//! Intra-layer parallel tiled execution: one layer spread over the
//! worker pool through a 2-D K×Y shard grid.
//!
//! The blocked loop nests of the paper expose outer levels of
//! *independent* work: iterations of an outer `K` split touch disjoint
//! output channels (and disjoint kernel rows), iterations of an outer
//! `Y` split touch disjoint output rows. PR 4's serving path already
//! exploited parallelism *across* batch images;
//! [`ParallelTiledBackend`] exploits it *within* one layer — the piece
//! that lets one big convolution scale across cores, matching how the
//! paper's x86 implementation (Sec. 5) and the DianNao-style
//! accelerators in PAPERS.md spread a layer over lanes.
//!
//! How a layer is gridded:
//!
//! 1. Pick the **grid axes**: for each of `K` and `Y`, the outermost
//!    *iterating* (trip >= 2) split of that dim, provided it sits at or
//!    above the level-0 tile boundary (trip-1 levels — extent-1 dims —
//!    only ever contribute offset zero and are skipped). When the `K`
//!    axis alone already offers at least one iteration per worker it is
//!    used 1-D (cells stay as coarse as the machine needs); otherwise
//!    both axes form a 2-D grid — which is what keeps every worker busy
//!    on the narrow-split plans where a single axis (say an outermost K
//!    split of trip 3 on 4 workers) would leave cores idle.
//! 2. Enumerate tile-aligned grid **cells** ([`NestShard`] per axis) in
//!    fixed row-major order, outer axis major — ragged counts allowed
//!    (a trip of 8 over 3 ranges gets 2/3/3 iterations).
//! 3. Workers on the shared [`crate::util::pool::WorkerPool`] **claim**
//!    cells through the atomic claim index of
//!    [`crate::util::pool::par_claim_with`] — work-stealing, so a
//!    worker finishing a small cell immediately takes the next one —
//!    and run each cell through the ordinary tiled execution path
//!    ([`super::TiledCpuBackend`]'s machinery), each with its own
//!    [`AccessCounters`](super::AccessCounters).
//! 4. Merge **in fixed cell order regardless of claim order**: output
//!    regions are disjoint (byte-identical to the serial tiled output
//!    at any worker count), and each buffer's counters are summed over
//!    exactly the cells whose restrictions scale that buffer's fills.
//!    A buffer created at position `c` refills once per iteration of
//!    every loop *above* `c`: an axis above `c` partitions those fills
//!    across its ranges (sum them), an axis at-or-below `c` repeats
//!    them identically in every range (count index 0 only). A cell
//!    therefore contributes a buffer iff every axis satisfies
//!    `pos > c || index == 0`. The same rule keyed off each tensor's
//!    outermost buffer settles the DRAM terminals. The merged report
//!    equals the per-MAC interpreter's exactly (`rust/tests/backend.rs`
//!    and `rust/tests/shard_grid.rs` pin it).
//!
//! Plans with no grid axis at all (e.g. a single-level string whose
//! whole nest is one tile) still execute serially, reported under the
//! honest `"parallel-serial"` label so counters never claim a fan-out
//! that did not happen.
//!
//! Fan-out is cheap because nothing is copied: `ConvInputs` tensors are
//! `Arc<[f32]>` (two refcount bumps per worker), the plan is shared
//! behind one `Arc`, and when the plan materializes no kernel buffer
//! outside the tile the whole weight repack is computed once
//! ([`super::nest`]-independent, immutable DRAM weights) and shared
//! read-only across workers ([`SharedPack`]).

use super::nest::NestShard;
use super::tiled::{execute_tiled, prepack_dram_weights, tile_boundary, SharedPack, Tile};
use super::{Backend, ConvInputs, ConvOutput, ExecLimits};
use crate::model::buffers::{allocate, BufferSet, Tensor};
use crate::model::dims::Dim;
use crate::model::string::BlockingString;
use crate::plan::BlockingPlan;
use crate::util::pool::{default_threads, par_claim_with, par_map_with, shared_pool};
use anyhow::{anyhow, ensure, Result};
use std::sync::Arc;

/// Intra-layer parallel tiled backend (see module docs). Registered as
/// `backend_by_name("parallel")` and the dispatch default for
/// `plan.execute(..)` whenever more than one worker thread is
/// available.
#[derive(Debug, Clone, Copy, Default)]
pub struct ParallelTiledBackend {
    /// Worker-count override: `0` (the default) follows
    /// [`default_threads`] (`CNNBLK_THREADS` /
    /// [`crate::util::pool::with_thread_cap`]); any other value sizes
    /// the grid for at most that many workers regardless of pool width.
    pub jobs: usize,
}

/// The grid axis of one dim: the outermost *iterating* (trip >= 2)
/// level of `dim`, provided it sits at or above the tile boundary.
/// Trip-1 levels (extent-1 dims) contribute only offset zero and are
/// skipped. Walking past an *iterating* level would break the
/// contiguous-region merge, so the first trip >= 2 level is the only
/// candidate; inside the tile it cannot be restricted, hence `None`.
fn axis_of(s: &BlockingString, boundary: usize, dim: Dim) -> Option<usize> {
    let pos = (0..s.len())
        .rev()
        .find(|&p| s.levels[p].dim == dim && s.trip(p) >= 2)?;
    (pos >= boundary).then_some(pos)
}

/// The string positions the grid shards over, outermost first. The `K`
/// axis is used alone when its trip already covers `workers` (one
/// iteration per worker; coarser cells mean fewer duplicated
/// above-the-grid fills), else K × Y when both exist, else whichever
/// axis exists, else empty (the layer has nothing to shard).
fn grid_axes(s: &BlockingString, boundary: usize, workers: u64) -> Vec<usize> {
    let k = axis_of(s, boundary, Dim::K);
    let y = axis_of(s, boundary, Dim::Y);
    let mut axes = match (k, y) {
        (Some(kp), Some(yp)) if s.trip(kp) < workers => vec![kp, yp],
        (Some(kp), _) => vec![kp],
        (None, Some(yp)) => vec![yp],
        (None, None) => Vec::new(),
    };
    // Fixed enumeration order: outermost (highest position) axis major.
    axes.sort_unstable_by(|a, b| b.cmp(a));
    axes
}

/// One cell of the shard grid: the per-axis iteration-range
/// restrictions handed to the nest, plus the per-axis range indices the
/// merge's accounting rule keys on.
#[derive(Debug, Clone)]
struct GridCell {
    shards: Vec<NestShard>,
    idx: Vec<usize>,
}

/// Enumerate the grid cells for `axes` in fixed row-major order (outer
/// axis major). Each axis with trip `T` is cut into `min(T, workers)`
/// contiguous ragged-safe ranges (`range w` = `[w*T/S, (w+1)*T/S)`).
fn grid_cells(s: &BlockingString, axes: &[usize], workers: u64) -> Vec<GridCell> {
    let per_axis: Vec<Vec<NestShard>> = axes
        .iter()
        .map(|&pos| {
            let trip = s.trip(pos);
            let n = trip.min(workers.max(1));
            (0..n)
                .map(|w| NestShard {
                    pos,
                    start: trip * w / n,
                    end: trip * (w + 1) / n,
                })
                .collect()
        })
        .collect();
    let mut cells = vec![GridCell {
        shards: Vec::new(),
        idx: Vec::new(),
    }];
    for ranges in &per_axis {
        let mut next = Vec::with_capacity(cells.len() * ranges.len());
        for cell in &cells {
            for (i, sh) in ranges.iter().enumerate() {
                let mut shards = cell.shards.clone();
                shards.push(*sh);
                let mut idx = cell.idx.clone();
                idx.push(i);
                next.push(GridCell { shards, idx });
            }
        }
        cells = next;
    }
    cells
}

/// The amount of independent intra-layer parallelism `plan` exposes:
/// the product of the grid axes' trip counts (outermost iterating `K`
/// split × outermost iterating `Y` split, each counted only when it
/// sits at or above the tile boundary), or `None` when the plan has no
/// grid axis and executes serially under the `"parallel-serial"` label.
/// This is the legality/width signal the serving scheduler uses to
/// decide whether intra-layer sharding is even worth scoring for a
/// layer.
pub fn shard_width(plan: &BlockingPlan) -> Option<u64> {
    let boundary = tile_boundary(&plan.string);
    let k = axis_of(&plan.string, boundary, Dim::K);
    let y = axis_of(&plan.string, boundary, Dim::Y);
    if k.is_none() && y.is_none() {
        return None;
    }
    let trip = |a: Option<usize>| a.map(|p| plan.string.trip(p)).unwrap_or(1);
    Some(trip(k) * trip(y))
}

/// The number of cells the shard grid would enumerate for `plan` at
/// `workers` workers; 0 when the plan has no grid axis (serial
/// execution). Exposed for the conformance suite in
/// `rust/tests/shard_grid.rs`.
#[doc(hidden)]
pub fn grid_cell_count(plan: &BlockingPlan, workers: usize) -> usize {
    let boundary = tile_boundary(&plan.string);
    let axes = grid_axes(&plan.string, boundary, workers.max(1) as u64);
    if axes.is_empty() {
        return 0;
    }
    grid_cells(&plan.string, &axes, workers.max(1) as u64).len()
}

/// Execute the shard grid with an *injected* claim order: cells are run
/// one at a time in the order `order` lists them (a permutation of
/// `0..grid_cell_count`), then merged in fixed cell order — exactly the
/// merge the racing pool path uses. The conformance suite drives this
/// to prove the merged result is independent of claim order, which the
/// nondeterministic atomic race cannot demonstrate on demand.
#[doc(hidden)]
pub fn execute_grid_claim_order(
    plan: &BlockingPlan,
    inputs: &ConvInputs,
    workers: usize,
    order: &[usize],
) -> Result<ConvOutput> {
    let boundary = tile_boundary(&plan.string);
    let axes = grid_axes(&plan.string, boundary, workers.max(1) as u64);
    ensure!(!axes.is_empty(), "plan has no grid axis to shard");
    let cells = grid_cells(&plan.string, &axes, workers.max(1) as u64);
    let mut seen = order.to_vec();
    seen.sort_unstable();
    ensure!(
        seen == (0..cells.len()).collect::<Vec<_>>(),
        "claim order {:?} is not a permutation of 0..{}",
        order,
        cells.len()
    );
    let mut outs: Vec<Option<ConvOutput>> = (0..cells.len()).map(|_| None).collect();
    for &ci in order {
        outs[ci] = Some(execute_tiled(
            plan,
            inputs,
            &cells[ci].shards,
            "parallel",
            None,
            ExecLimits::UNLIMITED,
        )?);
    }
    let outs = outs
        .into_iter()
        .map(|o| o.ok_or_else(|| anyhow!("internal: unexecuted cell")))
        .collect::<Result<Vec<_>>>()?;
    let bufs = allocate(&plan.string, &plan.dims);
    merge(plan, &cells, &bufs, outs)
}

/// The pre-grid single-axis algorithm (one axis, fixed per-worker range
/// assignment, no stealing), kept as the bench harness's baseline so
/// the `RaggedGate` CI gate can fail if the grid is ever slower than
/// 1-D sharding at the same worker count. Reports under the
/// `"parallel1d"` label.
#[doc(hidden)]
pub fn execute_single_axis(
    plan: &BlockingPlan,
    inputs: &ConvInputs,
    jobs: usize,
) -> Result<ConvOutput> {
    let boundary = tile_boundary(&plan.string);
    let s = &plan.string;
    let workers = if jobs > 0 { jobs } else { default_threads() } as u64;
    let axis = axis_of(s, boundary, Dim::K).or_else(|| axis_of(s, boundary, Dim::Y));
    let pos = match axis {
        Some(pos) if workers > 1 => pos,
        _ => return execute_tiled(plan, inputs, &[], "parallel1d", None, ExecLimits::UNLIMITED),
    };
    let cells = grid_cells(s, &[pos], workers);
    let bufs = allocate(s, &plan.dims);
    let shared_pack = dram_weight_pack(plan, &bufs, boundary, inputs);
    let outs: Vec<Result<ConvOutput>> = {
        let plan = Arc::new(plan.clone());
        let inputs = inputs.clone();
        let sp = shared_pack.clone();
        par_map_with(&shared_pool(), cells.clone(), move |cell| {
            execute_tiled(
                &plan,
                &inputs,
                &cell.shards,
                "parallel1d",
                sp.as_ref(),
                ExecLimits::UNLIMITED,
            )
        })?
    };
    let mut runs = Vec::with_capacity(outs.len());
    for out in outs {
        runs.push(out?);
    }
    merge(plan, &cells, &bufs, runs)
}

/// The shared read-only weight prepack, when sound: kernel buffers all
/// inside the tile means the tile kernel reads weights straight from
/// the immutable DRAM tensor — pack them once, shared across workers.
fn dram_weight_pack(
    plan: &BlockingPlan,
    bufs: &BufferSet,
    boundary: usize,
    inputs: &ConvInputs,
) -> Option<Arc<SharedPack>> {
    if bufs.kernel.iter().all(|vb| vb.created_at < boundary) {
        Some(Arc::new(prepack_dram_weights(
            &plan.dims,
            &Tile::of(plan, boundary),
            &inputs.weights,
        )))
    } else {
        None
    }
}

impl Backend for ParallelTiledBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn execute_with(
        &self,
        plan: &BlockingPlan,
        inputs: &ConvInputs,
        limits: ExecLimits,
    ) -> Result<ConvOutput> {
        let workers = if self.jobs > 0 {
            self.jobs
        } else {
            default_threads()
        };
        if workers <= 1 {
            // A single worker runs the plain tiled path — the grid
            // would enumerate one whole-layer cell anyway.
            return execute_tiled(plan, inputs, &[], "parallel", None, limits);
        }
        let boundary = tile_boundary(&plan.string);
        let axes = grid_axes(&plan.string, boundary, workers as u64);
        if axes.is_empty() {
            // No grid axis at all: honest provenance — this execution
            // was serial, its counters are a single nest's.
            return execute_tiled(plan, inputs, &[], "parallel-serial", None, limits);
        }
        let cells = grid_cells(&plan.string, &axes, workers as u64);
        let bufs = allocate(&plan.string, &plan.dims);
        let shared_pack = dram_weight_pack(plan, &bufs, boundary, inputs);

        let outs: Vec<Result<ConvOutput>> = {
            let plan = Arc::new(plan.clone());
            let inputs = inputs.clone();
            let sp = shared_pack.clone();
            par_claim_with(&shared_pool(), cells.clone(), move |_i, cell| {
                execute_tiled(&plan, &inputs, &cell.shards, "parallel", sp.as_ref(), limits)
            })?
        };
        let mut runs = Vec::with_capacity(outs.len());
        for out in outs {
            runs.push(out?);
        }
        merge(plan, &cells, &bufs, runs)
    }
}

/// Merge per-cell results deterministically, in fixed cell order:
/// disjoint output regions copied into the full tensor, counters summed
/// over exactly the cells whose restrictions scale each buffer's fills
/// (the `pos > created_at || index == 0` rule — module docs).
fn merge(
    plan: &BlockingPlan,
    cells: &[GridCell],
    bufs: &BufferSet,
    runs: Vec<ConvOutput>,
) -> Result<ConvOutput> {
    let d = plan.dims;
    let s = &plan.string;
    let (bb, kk, yy, xx) = (d.b as usize, d.k as usize, d.y as usize, d.x as usize);
    let plane = yy * xx;

    let mut output = vec![0f32; d.output_elems() as usize];
    for (cell, run) in cells.iter().zip(&runs) {
        ensure!(
            run.output.len() == output.len(),
            "internal: cell output length {} != layer output {}",
            run.output.len(),
            output.len()
        );
        // The cell's output region: its K range × its Y range, the full
        // extent along any axis the grid does not restrict.
        let (mut klo, mut khi, mut ylo, mut yhi) = (0usize, kk, 0usize, yy);
        for sh in &cell.shards {
            let dim = s.levels[sh.pos].dim;
            let stride = s.covered_below(sh.pos)[dim as usize] as usize;
            match dim {
                Dim::K => (klo, khi) = (sh.start as usize * stride, sh.end as usize * stride),
                Dim::Y => (ylo, yhi) = (sh.start as usize * stride, sh.end as usize * stride),
                other => unreachable!("grid axis is K or Y, got {}", other),
            }
        }
        for b in 0..bb {
            for k in klo..khi {
                let at = (b * kk + k) * plane + ylo * xx;
                let len = (yhi - ylo) * xx;
                output[at..at + len].copy_from_slice(&run.output[at..at + len]);
            }
        }
    }

    // A cell contributes a buffer created at `c` iff every axis either
    // sits above `c` (the cell ran a real share of that buffer's fills)
    // or is at range index 0 (the one representative of fills that
    // repeat identically across that axis's ranges).
    let contributes = |cell: &GridCell, created_at: usize| {
        cell.shards
            .iter()
            .zip(&cell.idx)
            .all(|(sh, &ix)| sh.pos > created_at || ix == 0)
    };
    // The DRAM terminal of a tensor rides its outermost buffer; a
    // tensor with no buffers has no block-transfer DRAM traffic (its
    // cold stream is operand traffic), so summing its zeros is safe.
    let dram_contributes = |cell: &GridCell, t: Tensor| {
        bufs.of(t)
            .last()
            .map(|vb| contributes(cell, vb.created_at))
            .unwrap_or(true)
    };

    // Start from cell 0 — every range index is 0 there, so it
    // contributes to every buffer — then fold the remaining cells in.
    let mut counters = runs[0].counters.clone();
    for (cell, run) in cells.iter().zip(&runs).skip(1) {
        counters.macs += run.counters.macs;
        counters.operand.input_reads += run.counters.operand.input_reads;
        counters.operand.kernel_reads += run.counters.operand.kernel_reads;
        counters.operand.output_accesses += run.counters.operand.output_accesses;
        ensure!(
            counters.buffers.len() == run.counters.buffers.len(),
            "internal: cell buffer reports diverge"
        );
        for (acc, b) in counters.buffers.iter_mut().zip(&run.counters.buffers) {
            let created_at = bufs.of(b.tensor)[b.ordinal].created_at;
            if !contributes(cell, created_at) {
                continue;
            }
            acc.fill_events += b.fill_events;
            acc.fill_elems += b.fill_elems;
            acc.writeback_elems += b.writeback_elems;
        }
        if dram_contributes(cell, Tensor::Input) {
            counters.dram.input_loads += run.counters.dram.input_loads;
        }
        if dram_contributes(cell, Tensor::Kernel) {
            counters.dram.kernel_loads += run.counters.dram.kernel_loads;
        }
        if dram_contributes(cell, Tensor::Output) {
            counters.dram.output_loads += run.counters.dram.output_loads;
            counters.dram.output_stores += run.counters.dram.output_stores;
        }
    }
    ensure!(
        counters.macs == d.macs(),
        "internal: merged cells executed {} MACs, layer has {}",
        counters.macs,
        d.macs()
    );
    Ok(ConvOutput { output, counters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::dims::LayerDims;

    fn parse(d: &LayerDims, s: &str) -> BlockingString {
        let b = BlockingString::parse(s).unwrap().with_window(d);
        b.validate(d).unwrap();
        b
    }

    #[test]
    fn axis_prefers_outermost_iterating_k() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        // boundary 6; outermost iterating K is K1 at position 7, trip 2
        let b = tile_boundary(&s);
        assert_eq!(axis_of(&s, b, Dim::K), Some(7));
        assert_eq!(grid_axes(&s, b, 2), vec![7]);
    }

    #[test]
    fn axis_falls_back_to_y_then_none() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        // K only inside the tile: fall back to the outermost Y split.
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=4 K0=4 X1=8 Y1=8");
        let b = tile_boundary(&s);
        assert_eq!(axis_of(&s, b, Dim::K), None);
        assert_eq!(grid_axes(&s, b, 4), vec![7]); // Y1
        // single-level string: everything is one tile, nothing to shard
        let s = parse(&d, "Fw Fh C0=4 K0=4 X0=8 Y0=8");
        assert!(grid_axes(&s, tile_boundary(&s), 4).is_empty());
    }

    #[test]
    fn grid_goes_2d_only_when_k_is_narrower_than_workers() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=4 K0=2 K1=4 X1=8 Y1=8");
        let b = tile_boundary(&s);
        // K1 trip 2, Y1 trip 2. Two workers: K alone saturates.
        assert_eq!(grid_axes(&s, b, 2).len(), 1);
        // Four workers: K alone cannot, so the grid takes K × Y.
        let axes = grid_axes(&s, b, 4);
        assert_eq!(axes.len(), 2);
        assert!(axes[0] > axes[1], "outer axis must come first");
        let cells = grid_cells(&s, &axes, 4);
        assert_eq!(cells.len(), 4); // 2 K ranges × 2 Y ranges
        // fixed row-major order, outer axis major
        let idx: Vec<Vec<usize>> = cells.iter().map(|c| c.idx.clone()).collect();
        assert_eq!(idx, vec![vec![0, 0], vec![0, 1], vec![1, 0], vec![1, 1]]);
    }

    #[test]
    fn shard_width_is_the_product_of_axis_trips() {
        use crate::plan::{Planner, Target};
        let plan = Planner::for_named("t", LayerDims::conv(8, 8, 4, 4, 3, 3))
            .target(Target::Bespoke {
                budget_bytes: 64 * 1024,
            })
            .levels(2)
            .plan()
            .unwrap();
        let b = tile_boundary(&plan.string);
        let k = axis_of(&plan.string, b, Dim::K);
        let y = axis_of(&plan.string, b, Dim::Y);
        match (k, y) {
            (None, None) => assert_eq!(shard_width(&plan), None),
            _ => {
                let t = |a: Option<usize>| a.map(|p| plan.string.trip(p)).unwrap_or(1);
                assert_eq!(shard_width(&plan), Some(t(k) * t(y)));
                assert!(shard_width(&plan).unwrap() >= 2);
            }
        }
    }

    #[test]
    fn ranges_partition_ragged_trips() {
        // 3 ranges over a split of 8: 2/3/3 contiguous iterations.
        let d = LayerDims::conv(8, 8, 4, 32, 3, 3);
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=4 K0=4 X1=8 Y1=8 K1=32");
        let b = tile_boundary(&s);
        let axes = grid_axes(&s, b, 3);
        let cells = grid_cells(&s, &axes, 3);
        let ranges: Vec<(u64, u64)> = cells
            .iter()
            .map(|c| (c.shards[0].start, c.shards[0].end))
            .collect();
        assert_eq!(ranges, vec![(0, 2), (2, 5), (5, 8)]);
    }
}
