//! Intra-layer parallel tiled execution: one layer sharded across the
//! worker pool.
//!
//! The blocked loop nests of the paper expose an outermost level of
//! *independent* work: iterations of the outermost `K` split touch
//! disjoint output channels (and disjoint kernel rows), iterations of
//! the outermost `Y` split touch disjoint output rows. PR 4's serving
//! path already exploited parallelism *across* batch images;
//! [`ParallelTiledBackend`] exploits it *within* one layer — the piece
//! that lets one big convolution scale across cores, matching how the
//! paper's x86 implementation (Sec. 5) and the DianNao-style
//! accelerators in PAPERS.md spread a layer over lanes.
//!
//! How a layer is sharded:
//!
//! 1. Pick the shard level: the **outermost K split** of the plan's
//!    blocking string, falling back to the outermost `Y` split when `K`
//!    is unsplit outside the level-0 tile or too narrow to shard
//!    (trip < 2). Both leave the compiled tile kernel untouched — the
//!    restriction applies to a walked level at or above the tile
//!    boundary.
//! 2. Partition that level's trip count into contiguous per-worker
//!    iteration ranges ([`NestShard`]) — ragged counts allowed (3
//!    workers over a split of 8 get 2/3/3 iterations).
//! 3. Run each shard through the ordinary tiled execution path
//!    ([`super::TiledCpuBackend`]'s machinery) on the shared
//!    [`crate::util::pool::WorkerPool`], each worker with its own
//!    [`AccessCounters`](super::AccessCounters).
//! 4. Merge deterministically, in fixed shard order: output regions are
//!    disjoint (byte-identical to the serial tiled output at any worker
//!    count), per-buffer counters **sum** for buffers created below the
//!    shard level (each worker ran its share of the enclosing trips),
//!    and are **accounted once** for buffers created at or above it —
//!    those fills cross the shard boundary and are identical in every
//!    worker, so summing would double-count what the model charges a
//!    single execution. The same rule keyed off each tensor's outermost
//!    buffer settles the DRAM terminals. The merged report equals the
//!    per-MAC interpreter's exactly (`rust/tests/backend.rs` pins it).
//!
//! Fan-out is cheap because nothing is copied: `ConvInputs` tensors are
//! `Arc<[f32]>` (two refcount bumps per worker), the plan is shared
//! behind one `Arc`, and when the plan materializes no kernel buffer
//! outside the tile the whole weight repack is computed once
//! ([`super::nest`]-independent, immutable DRAM weights) and shared
//! read-only across workers ([`SharedPack`]).

use super::nest::NestShard;
use super::tiled::{execute_tiled, prepack_dram_weights, tile_boundary, SharedPack, Tile};
use super::{Backend, ConvInputs, ConvOutput};
use crate::model::buffers::{allocate, BufferSet, Tensor};
use crate::model::dims::Dim;
use crate::model::string::BlockingString;
use crate::plan::BlockingPlan;
use crate::util::pool::{default_threads, par_map_with, shared_pool};
use anyhow::{ensure, Result};
use std::sync::Arc;

/// Intra-layer parallel tiled backend (see module docs). Registered as
/// `backend_by_name("parallel")` and the dispatch default for
/// `plan.execute(..)` whenever more than one worker thread is
/// available.
#[derive(Debug, Clone, Copy, Default)]
pub struct ParallelTiledBackend {
    /// Worker-count override: `0` (the default) follows
    /// [`default_threads`] (`CNNBLK_THREADS` /
    /// [`crate::util::pool::with_thread_cap`]); any other value shards
    /// into at most that many ranges regardless of pool width.
    pub jobs: usize,
}

/// The string position to shard: the outermost `K` split at or above
/// the tile boundary with at least 2 iterations, else the outermost `Y`
/// split under the same conditions, else `None` (the layer runs
/// serially — e.g. a single-level string whose whole nest is one tile).
fn shard_level(s: &BlockingString, boundary: usize) -> Option<usize> {
    for dim in [Dim::K, Dim::Y] {
        if let Some(pos) = s.levels.iter().rposition(|l| l.dim == dim) {
            if pos >= boundary && s.trip(pos) >= 2 {
                return Some(pos);
            }
        }
    }
    None
}

/// The number of independent shards [`ParallelTiledBackend`] can split
/// `plan` into: the trip count of the shard level (outermost `K` split
/// at or above the tile boundary with trip >= 2, else the outermost `Y`
/// split), or `None` when the plan has no shardable level and executes
/// serially under the "parallel" label. This is the legality/width
/// signal the serving scheduler uses to decide whether intra-layer
/// sharding is even worth scoring for a layer.
pub fn shard_width(plan: &BlockingPlan) -> Option<u64> {
    let boundary = tile_boundary(&plan.string);
    shard_level(&plan.string, boundary).map(|pos| plan.string.trip(pos))
}

impl Backend for ParallelTiledBackend {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn execute(&self, plan: &BlockingPlan, inputs: &ConvInputs) -> Result<ConvOutput> {
        let boundary = tile_boundary(&plan.string);
        let workers = if self.jobs > 0 {
            self.jobs
        } else {
            default_threads()
        };
        let pos = match shard_level(&plan.string, boundary) {
            Some(pos) if workers > 1 => pos,
            // Nothing shardable (or a single worker): the plain tiled
            // path, reported under this backend's name.
            _ => return execute_tiled(plan, inputs, None, "parallel", None),
        };
        let trip = plan.string.trip(pos);
        let shards = (workers as u64).min(trip);

        // Kernel buffers all inside the tile means the tile kernel reads
        // weights straight from the immutable DRAM tensor — pack them
        // once, shared read-only across every worker.
        let bufs = allocate(&plan.string, &plan.dims);
        let shared_pack = if bufs.kernel.iter().all(|vb| vb.created_at < boundary) {
            Some(Arc::new(prepack_dram_weights(
                &plan.dims,
                &Tile::of(plan, boundary),
                &inputs.weights,
            )))
        } else {
            None
        };

        // Contiguous iteration ranges, ragged-safe: shard w runs
        // [w*T/S, (w+1)*T/S) — non-empty whenever S <= T.
        let ranges: Vec<NestShard> = (0..shards)
            .map(|w| NestShard {
                pos,
                start: trip * w / shards,
                end: trip * (w + 1) / shards,
            })
            .collect();

        let outs: Vec<Result<ConvOutput>> = {
            let plan = Arc::new(plan.clone());
            let inputs = inputs.clone();
            let sp = shared_pack.clone();
            par_map_with(&shared_pool(), ranges.clone(), move |sh| {
                execute_tiled(&plan, &inputs, Some(sh), "parallel", sp.as_ref())
            })
        };
        let mut shards_out = Vec::with_capacity(outs.len());
        for out in outs {
            shards_out.push(out?);
        }
        merge(plan, pos, &ranges, &bufs, shards_out)
    }
}

/// Merge per-shard results deterministically (fixed shard order):
/// disjoint output regions copied into the full tensor, counters summed
/// or accounted once per the shard-boundary rule (module docs).
fn merge(
    plan: &BlockingPlan,
    pos: usize,
    ranges: &[NestShard],
    bufs: &BufferSet,
    shards: Vec<ConvOutput>,
) -> Result<ConvOutput> {
    let d = plan.dims;
    let dim = plan.string.levels[pos].dim;
    // Extent of `dim` covered per iteration of the shard level.
    let stride = plan.string.covered_below(pos)[dim as usize] as usize;
    let (bb, kk, yy, xx) = (
        d.b as usize,
        d.k as usize,
        d.y as usize,
        d.x as usize,
    );
    let plane = yy * xx;

    let mut output = vec![0f32; d.output_elems() as usize];
    for (sh, run) in ranges.iter().zip(&shards) {
        ensure!(
            run.output.len() == output.len(),
            "internal: shard output length {} != layer output {}",
            run.output.len(),
            output.len()
        );
        let (lo, hi) = (sh.start as usize * stride, sh.end as usize * stride);
        match dim {
            Dim::K => {
                // Rows [lo, hi) of the K axis, per image.
                for b in 0..bb {
                    let at = (b * kk + lo) * plane;
                    let len = (hi - lo) * plane;
                    output[at..at + len].copy_from_slice(&run.output[at..at + len]);
                }
            }
            Dim::Y => {
                // Rows [lo, hi) of the Y axis, per (image, channel).
                for b in 0..bb {
                    for k in 0..kk {
                        let at = (b * kk + k) * plane + lo * xx;
                        let len = (hi - lo) * xx;
                        output[at..at + len].copy_from_slice(&run.output[at..at + len]);
                    }
                }
            }
            other => unreachable!("shard level is K or Y, got {}", other),
        }
    }

    // Counters: start from shard 0 (operand levels, buffer identities
    // and every at-or-above-the-boundary value are identical in all
    // shards), then fold the remaining shards in.
    let mut counters = shards[0].counters.clone();
    // True when the fills of tensor `t`'s outermost buffer — the DRAM
    // terminal of its chain — cross the shard boundary (account once).
    let dram_once = |t: Tensor| {
        bufs.of(t)
            .last()
            .map(|vb| vb.created_at >= pos)
            .unwrap_or(false)
    };
    for run in &shards[1..] {
        counters.macs += run.counters.macs;
        counters.operand.input_reads += run.counters.operand.input_reads;
        counters.operand.kernel_reads += run.counters.operand.kernel_reads;
        counters.operand.output_accesses += run.counters.operand.output_accesses;
        ensure!(
            counters.buffers.len() == run.counters.buffers.len(),
            "internal: shard buffer reports diverge"
        );
        for (acc, b) in counters.buffers.iter_mut().zip(&run.counters.buffers) {
            let created_at = bufs.of(b.tensor)[b.ordinal].created_at;
            if created_at >= pos {
                // Fills crossing the shard boundary: every worker
                // performed the identical (re)fill of this buffer, but a
                // single execution of the layer pays it once.
                continue;
            }
            acc.fill_events += b.fill_events;
            acc.fill_elems += b.fill_elems;
            acc.writeback_elems += b.writeback_elems;
        }
        if !dram_once(Tensor::Input) {
            counters.dram.input_loads += run.counters.dram.input_loads;
        }
        if !dram_once(Tensor::Kernel) {
            counters.dram.kernel_loads += run.counters.dram.kernel_loads;
        }
        if !dram_once(Tensor::Output) {
            counters.dram.output_loads += run.counters.dram.output_loads;
            counters.dram.output_stores += run.counters.dram.output_stores;
        }
    }
    ensure!(
        counters.macs == d.macs(),
        "internal: merged shards executed {} MACs, layer has {}",
        counters.macs,
        d.macs()
    );
    Ok(ConvOutput { output, counters })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::dims::LayerDims;

    fn parse(d: &LayerDims, s: &str) -> BlockingString {
        let b = BlockingString::parse(s).unwrap().with_window(d);
        b.validate(d).unwrap();
        b
    }

    #[test]
    fn shard_level_prefers_outermost_k() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        // boundary 6; outermost K is K1 at position 7 with trip 2
        assert_eq!(shard_level(&s, tile_boundary(&s)), Some(7));
    }

    #[test]
    fn shard_level_falls_back_to_y_then_none() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        // K only inside the tile: fall back to the outermost Y split.
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=4 K0=4 X1=8 Y1=8");
        let b = tile_boundary(&s);
        assert_eq!(shard_level(&s, b), Some(7)); // Y1
        // single-level string: everything is one tile, nothing to shard
        let s = parse(&d, "Fw Fh C0=4 K0=4 X0=8 Y0=8");
        assert_eq!(shard_level(&s, tile_boundary(&s)), None);
    }

    #[test]
    fn shard_width_reports_the_shard_level_trip() {
        use crate::plan::{Planner, Target};
        let plan = Planner::for_named("t", LayerDims::conv(8, 8, 4, 4, 3, 3))
            .target(Target::Bespoke {
                budget_bytes: 64 * 1024,
            })
            .levels(2)
            .plan()
            .unwrap();
        let b = tile_boundary(&plan.string);
        match shard_level(&plan.string, b) {
            Some(pos) => assert_eq!(shard_width(&plan), Some(plan.string.trip(pos))),
            None => assert_eq!(shard_width(&plan), None),
        }
        if let Some(w) = shard_width(&plan) {
            assert!(w >= 2, "shardable plans expose at least 2 shards, got {w}");
        }
    }

    #[test]
    fn ranges_partition_ragged_trips() {
        // 3 workers over a K split 8 ways: 2/3/3 contiguous iterations.
        let trip = 8u64;
        let shards = 3u64;
        let ranges: Vec<(u64, u64)> = (0..shards)
            .map(|w| (trip * w / shards, trip * (w + 1) / shards))
            .collect();
        assert_eq!(ranges, vec![(0, 2), (2, 5), (5, 8)]);
    }
}
