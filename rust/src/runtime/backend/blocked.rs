//! The blocked CPU backend: a per-MAC loop-nest interpreter for
//! blocking plans.
//!
//! [`BlockedCpuBackend`] executes a plan exactly the way the paper's
//! model reasons about it: the blocking string *is* the loop nest
//! (innermost → outermost), every Table 2 virtual buffer becomes a real
//! `f32` buffer sized to its block footprint and labelled with the
//! physical level the plan placed it on, and data moves in whole blocks
//! — a buffer refills from its parent (the next-outer buffer of the
//! same tensor, or DRAM) every time *any* enclosing loop iterates,
//! which is the model semantics `model::access` charges through the
//! Table 2 refetch-rate chain. Output buffers hold partial sums: they
//! load partials from the parent on fill and write them back on exit,
//! so accumulation is numerically exact across refills.
//!
//! The nest machinery (buffer geometry, fills, writebacks, walker,
//! counters) lives in [`super::nest`] and is shared with the
//! [`super::TiledCpuBackend`] fast path; what makes this backend the
//! *interpreter* is its leaf: it recurses through every loop level and
//! executes one multiply-accumulate per innermost point
//! (`Nest::mac_at`), materializing every Table 2 buffer. That makes it
//! the slowest backend (~tens of ns per MAC) and the most literal one —
//! the per-MAC oracle the tiled path's tile kernel is checked against.
//!
//! Because fills follow model semantics and Table 2 input blocks never
//! clip at image edges (the halo'd input is exactly
//! `(X+Fw-1) x (Y+Fh-1)` — every block, including the last along each
//! axis, lies fully inside it), the measured per-buffer fill counts
//! equal the model's `fill_events`/`fill_elems` *exactly*; the pinned
//! tolerance in `rust/tests/backend.rs` only absorbs f64 rounding in
//! the model's trip-count products.
//!
//! Cost: `dims.macs()` interpreted MAC steps plus the block-copy
//! traffic (roughly the predicted fill totals). Meant for the scaled
//! benchmark dims (`LayerDims::scaled_for_sim`) and as the oracle in
//! tests/benches; for anything throughput-sensitive (`cnnblk run` at
//! large `--max-macs`, serving) use the tiled backend, which is the
//! dispatch default.

use super::nest::Nest;
use super::{Backend, ConvInputs, ConvOutput, ExecLimits};
use crate::plan::BlockingPlan;
use anyhow::Result;

/// Per-MAC loop-nest interpreter backend (see module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockedCpuBackend;

impl Backend for BlockedCpuBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn execute_with(
        &self,
        plan: &BlockingPlan,
        inputs: &ConvInputs,
        limits: ExecLimits,
    ) -> Result<ConvOutput> {
        // Boundary 0: every loop level is walked, every buffer is
        // materialized, and the leaf is a single interpreted MAC.
        let mut nest = Nest::new(plan, inputs, 0, limits)?;
        nest.run(&mut |n, off| n.mac_at(off));
        nest.finish("blocked")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::naive_conv::conv_valid;
    use crate::model::buffers::{allocate, Tensor};
    use crate::model::dims::LayerDims;
    use crate::model::string::BlockingString;
    use crate::plan::{Planner, Provenance, Target};

    fn manual_plan(d: LayerDims, s: &str) -> BlockingPlan {
        let string = BlockingString::parse(s).unwrap().with_window(&d);
        BlockingPlan::evaluate(
            "t",
            d,
            string,
            Provenance::external(
                Target::Bespoke {
                    budget_bytes: 8 << 20,
                },
                "manual",
            ),
        )
        .unwrap()
    }

    fn naive_of(d: &LayerDims, inputs: &ConvInputs) -> Vec<f32> {
        let (h, w) = ((d.y + d.fh - 1) as usize, (d.x + d.fw - 1) as usize);
        let image = d.c as usize * h * w;
        let mut out = Vec::new();
        for b in 0..d.b as usize {
            out.extend(conv_valid(
                &inputs.input[b * image..(b + 1) * image],
                (d.c as usize, h, w),
                &inputs.weights,
                (d.k as usize, d.c as usize, d.fh as usize, d.fw as usize),
            ));
        }
        out
    }

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let rel = (x - y).abs() / x.abs().max(y.abs()).max(1.0);
            assert!(rel < 1e-4, "elem {}: {} vs {} (rel {})", i, x, y, rel);
        }
    }

    #[test]
    fn deep_blocking_matches_oracle() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        let inputs = ConvInputs::synthetic(d, 3);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
    }

    #[test]
    fn single_level_blocking_matches_oracle() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        for s in [
            "Fw Fh X0=8 Y0=8 C0=4 K0=2 K1=4",
            "Fw Fh X0=2 Y0=2 C0=4 K0=4 X1=8 Y1=8",
            "Fw Fh C0=4 K0=4 X0=8 Y0=8",
        ] {
            let plan = manual_plan(d, s);
            let inputs = ConvInputs::synthetic(d, 9);
            let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
            assert_close(&got.output, &naive_of(&d, &inputs));
        }
    }

    #[test]
    fn fc_with_batch_matches_oracle() {
        let d = LayerDims::fc(16, 8, 4);
        let plan = manual_plan(d, "Fw Fh C0=4 K0=8 B0=4 C1=16");
        let inputs = ConvInputs::synthetic(d, 1);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
    }

    #[test]
    fn fill_counts_match_the_interpreter_oracle() {
        // `model::validate::simulate` is the existing fill-count oracle;
        // the executing backend must agree with it buffer for buffer.
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        let inputs = ConvInputs::synthetic(d, 2);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        let bufs = allocate(&plan.string, &d);
        let sims = crate::model::validate::simulate(&plan.string, &d, &bufs);
        for sim in sims {
            let m = got
                .counters
                .buffers
                .iter()
                .find(|b| b.tensor == sim.tensor && b.ordinal == sim.ordinal)
                .unwrap();
            assert_eq!(m.fill_events, sim.model_fills, "{}{}", sim.tensor, sim.ordinal);
        }
    }

    #[test]
    fn output_partials_survive_eviction() {
        // C split above a K loop forces the output block to round-trip
        // through its parent mid-accumulation; numerics must be exact.
        let d = LayerDims::conv(4, 4, 8, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=4 C1=8");
        let inputs = ConvInputs::synthetic(d, 4);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
        // the outermost OB really did write back more than once
        let ob = got
            .counters
            .chain(Tensor::Output)
            .last()
            .cloned()
            .cloned();
        let ob = ob.unwrap();
        assert!(ob.fill_events >= 1);
    }

    #[test]
    fn planned_layer_matches_oracle() {
        let d = LayerDims::conv(16, 16, 8, 8, 3, 3);
        let plan = Planner::for_named("p", d).levels(2).plan().unwrap();
        let inputs = ConvInputs::synthetic(d, 8);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
        // counters carry the plan's placement labels
        for b in &got.counters.buffers {
            assert!(
                plan.buffers
                    .iter()
                    .any(|pb| pb.tensor == b.tensor && pb.ordinal == b.ordinal && pb.level == b.level),
                "no placement for {}{} at {}",
                b.tensor,
                b.ordinal,
                b.level
            );
        }
    }

    #[test]
    fn dims_mismatch_is_an_error() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh C0=4 K0=4 X0=8 Y0=8");
        let other = ConvInputs::synthetic(LayerDims::conv(6, 6, 4, 4, 3, 3), 0);
        assert!(BlockedCpuBackend.execute(&plan, &other).is_err());
    }

    #[test]
    fn hoisted_window_strings_are_rejected() {
        // Table 2 sizes buffers under a hoisted window loop without the
        // swept window extent, so they are not executable as-is; the
        // backend must refuse rather than read out of block.
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "X0=2 Fw Fh X1=8 Y0=8 C0=4 K0=4");
        let inputs = ConvInputs::synthetic(d, 1);
        let err = BlockedCpuBackend.execute(&plan, &inputs).unwrap_err();
        assert!(err.to_string().contains("hoisted"), "{}", err);
        // ...but window dims of extent 1 may sit anywhere (FC layers).
        let fc = LayerDims::fc(16, 8, 1);
        let fc_plan = manual_plan(fc, "C0=4 K0=8 C1=16 Fw Fh");
        assert!(BlockedCpuBackend
            .execute(&fc_plan, &ConvInputs::synthetic(fc, 2))
            .is_ok());
    }
}
