//! The blocked CPU backend: a loop-nest interpreter for blocking plans.
//!
//! [`BlockedCpuBackend`] executes a plan exactly the way the paper's
//! model reasons about it: the blocking string *is* the loop nest
//! (innermost → outermost), every Table 2 virtual buffer becomes a real
//! `f32` buffer sized to its block footprint and labelled with the
//! physical level the plan placed it on, and data moves in whole blocks
//! — a buffer refills from its parent (the next-outer buffer of the
//! same tensor, or DRAM) every time *any* enclosing loop iterates,
//! which is the model semantics `model::access` charges through the
//! Table 2 refetch-rate chain. Output buffers hold partial sums: they
//! load partials from the parent on fill and write them back on exit,
//! so accumulation is numerically exact across refills.
//!
//! Because fills follow model semantics and Table 2 input blocks never
//! clip at image edges (the halo'd input is exactly
//! `(X+Fw-1) x (Y+Fh-1)` — every block, including the last along each
//! axis, lies fully inside it), the measured per-buffer fill counts
//! equal the model's `fill_events`/`fill_elems` *exactly*; the pinned
//! tolerance in `rust/tests/backend.rs` only absorbs f64 rounding in
//! the model's trip-count products.
//!
//! Cost: `dims.macs()` interpreted MAC steps plus the block-copy
//! traffic (roughly the predicted fill totals). Meant for the scaled
//! benchmark dims (`LayerDims::scaled_for_sim`) and the e2e pipeline
//! layers; executing a full-size Table 4 layer (10^12 MACs) through an
//! interpreter is not realistic — `cnnblk run` scales dims down before
//! planning for exactly this reason.

use super::{
    AccessCounters, Backend, BufferCounters, ConvInputs, ConvOutput, DramCounters,
    OperandCounters,
};
use crate::model::buffers::{allocate, Tensor};
use crate::model::dims::Dim;
use crate::plan::BlockingPlan;
use anyhow::{anyhow, ensure, Result};

/// Loop-nest interpreter backend (see module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct BlockedCpuBackend;

impl Backend for BlockedCpuBackend {
    fn name(&self) -> &'static str {
        "blocked"
    }

    fn execute(&self, plan: &BlockingPlan, inputs: &ConvInputs) -> Result<ConvOutput> {
        let d = plan.dims;
        ensure!(
            inputs.dims == d,
            "inputs are for {} but the plan is for {}",
            inputs.dims,
            d
        );
        plan.string
            .validate(&d)
            .map_err(|e| anyhow!("plan string '{}' invalid for {}: {}", plan.string, d, e))?;
        ensure!(
            inputs.input.len() as u64 == d.input_elems()
                && inputs.weights.len() as u64 == d.kernel_elems(),
            "input/weight tensors do not match {}",
            d
        );
        let mut interp = Interp::new(plan, inputs)?;
        interp.run();
        interp.finish(&d)
    }
}

/// One real buffer backing a Table 2 virtual buffer during execution.
/// (Its creation position lives in `Interp::by_pos`.)
struct Block {
    tensor: Tensor,
    ordinal: usize,
    /// Physical level the plan placed it on (counter label only).
    level: String,
    /// Block extents in the tensor's axis order (see `axes of` below).
    dims4: [u64; 4],
    /// Global origin of the currently-held block, same axis order.
    origin: [u64; 4],
    data: Vec<f32>,
    fill_events: u64,
    fill_elems: u64,
    writeback_elems: u64,
}

/// One loop level of the nest, precomputed from the blocking string.
struct LoopLevel {
    dim: Dim,
    trip: u64,
    /// Step of the dim's global offset per iteration (covered extent of
    /// the dim strictly below this position).
    stride: u64,
}

/// Axis order per tensor, chosen to match the DRAM layouts so the DRAM
/// "parent" is just a block with full extents and origin zero:
/// input `(B, C, H, W)`, kernel `(K, C, Fh, Fw)`, output `(B, K, Y, X)`.
fn block_geometry(t: Tensor, cov: &[u64; 7]) -> [u64; 4] {
    let g = |d: Dim| cov[d as usize];
    match t {
        Tensor::Input => [
            g(Dim::B),
            g(Dim::C),
            g(Dim::Y) + g(Dim::Fh) - 1,
            g(Dim::X) + g(Dim::Fw) - 1,
        ],
        Tensor::Kernel => [g(Dim::K), g(Dim::C), g(Dim::Fh), g(Dim::Fw)],
        Tensor::Output => [g(Dim::B), g(Dim::K), g(Dim::Y), g(Dim::X)],
    }
}

/// Global block origin for a tensor given the enclosing-loop offsets.
/// Input rows/cols fold the window offset in (`h = y + fh`).
fn block_origin(t: Tensor, off: &[u64; 7]) -> [u64; 4] {
    let o = |d: Dim| off[d as usize];
    match t {
        Tensor::Input => [
            o(Dim::B),
            o(Dim::C),
            o(Dim::Y) + o(Dim::Fh),
            o(Dim::X) + o(Dim::Fw),
        ],
        Tensor::Kernel => [o(Dim::K), o(Dim::C), o(Dim::Fh), o(Dim::Fw)],
        Tensor::Output => [o(Dim::B), o(Dim::K), o(Dim::Y), o(Dim::X)],
    }
}

/// Flat index of global coordinate `g` inside an array of extents
/// `dims4` whose element [0,0,0,0] sits at global `origin`.
#[inline]
fn idx4(dims4: &[u64; 4], origin: &[u64; 4], g: &[u64; 4]) -> usize {
    let l0 = g[0] - origin[0];
    let l1 = g[1] - origin[1];
    let l2 = g[2] - origin[2];
    let l3 = g[3] - origin[3];
    debug_assert!(
        l0 < dims4[0] && l1 < dims4[1] && l2 < dims4[2] && l3 < dims4[3],
        "coordinate {:?} outside block {:?}@{:?}",
        g,
        dims4,
        origin
    );
    (((l0 * dims4[1] + l1) * dims4[2] + l2) * dims4[3] + l3) as usize
}

/// Copy the whole `region`-sized block at global origin `gorg` from
/// `(src, sdims, sorg)` into `(dst, ddims, dorg)`; returns elements
/// moved. Rows (the last axis) are copied contiguously.
#[allow(clippy::too_many_arguments)] // (array, dims, origin) x2 + region
fn copy_region(
    src: &[f32],
    sdims: &[u64; 4],
    sorg: &[u64; 4],
    dst: &mut [f32],
    ddims: &[u64; 4],
    dorg: &[u64; 4],
    region: &[u64; 4],
    gorg: &[u64; 4],
) -> u64 {
    let w = region[3] as usize;
    for a0 in 0..region[0] {
        for a1 in 0..region[1] {
            for a2 in 0..region[2] {
                let g = [gorg[0] + a0, gorg[1] + a1, gorg[2] + a2, gorg[3]];
                let si = idx4(sdims, sorg, &g);
                let di = idx4(ddims, dorg, &g);
                dst[di..di + w].copy_from_slice(&src[si..si + w]);
            }
        }
    }
    region[0] * region[1] * region[2] * region[3]
}

/// Refill buffer `i` of `chain` at `origin`: copy its block from the
/// next-outer buffer, or from the DRAM-resident tensor (bumping that
/// tensor's DRAM-load counter) when `i` is the outermost.
fn fill_chain(
    chain: &mut [Block],
    i: usize,
    origin: [u64; 4],
    dram_src: &[f32],
    dram_dims: &[u64; 4],
    dram_loads: &mut u64,
) {
    let (child, parent) = chain.split_at_mut(i + 1);
    let b = &mut child[i];
    b.origin = origin;
    let n = match parent.first() {
        Some(par) => copy_region(
            &par.data, &par.dims4, &par.origin, &mut b.data, &b.dims4, &b.origin, &b.dims4,
            &b.origin,
        ),
        None => {
            let n = copy_region(
                dram_src, dram_dims, &[0; 4], &mut b.data, &b.dims4, &b.origin, &b.dims4,
                &b.origin,
            );
            *dram_loads += n;
            n
        }
    };
    b.fill_events += 1;
    b.fill_elems += n;
}

struct Interp<'a> {
    levels: Vec<LoopLevel>,
    /// Buffers created at each string position, as (tensor, chain index).
    by_pos: Vec<Vec<(Tensor, usize)>>,
    input_chain: Vec<Block>,
    kernel_chain: Vec<Block>,
    output_chain: Vec<Block>,
    dram_in: &'a [f32],
    dram_w: &'a [f32],
    dram_out: Vec<f32>,
    in_dims: [u64; 4],
    w_dims: [u64; 4],
    out_dims: [u64; 4],
    dram: DramCounters,
    macs_done: u64,
}

impl<'a> Interp<'a> {
    fn new(plan: &BlockingPlan, inputs: &'a ConvInputs) -> Result<Interp<'a>> {
        let d = plan.dims;
        let s = &plan.string;
        let n = s.len();

        // Table 2 sizes a buffer created at-or-below a hoisted window
        // loop *without* the window extent that loop sweeps (the model
        // charges the re-reads through the refetch-rate chain instead),
        // so such a buffer physically cannot serve the window's reads —
        // executing it would index outside the block. The optimizer
        // never hoists Fw/Fh (they stay innermost); reject the rare
        // hand-written string that does.
        let first_nonwindow = s
            .levels
            .iter()
            .position(|l| !matches!(l.dim, Dim::Fw | Dim::Fh))
            .unwrap_or(n);
        if let Some(hoisted) = s.levels[first_nonwindow.min(n)..]
            .iter()
            .find(|l| matches!(l.dim, Dim::Fw | Dim::Fh) && l.range > 1)
        {
            return Err(anyhow!(
                "blocked backend cannot execute '{}': window loop {} is hoisted \
                 above other loops (Fw/Fh must be innermost)",
                s,
                hoisted.dim
            ));
        }

        let mut levels = Vec::with_capacity(n);
        for i in 0..n {
            let dim = s.levels[i].dim;
            let stride = s.covered_below(i)[dim as usize];
            levels.push(LoopLevel {
                dim,
                trip: s.trip(i),
                stride,
            });
        }

        let bufs = allocate(s, &d);
        let mut by_pos: Vec<Vec<(Tensor, usize)>> = vec![Vec::new(); n];
        let mut chains: [Vec<Block>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        for (ci, t) in Tensor::ALL.into_iter().enumerate() {
            for vb in bufs.of(t) {
                let cov = s.covered_below(vb.created_at);
                let dims4 = block_geometry(t, &cov);
                let elems = dims4.iter().product::<u64>();
                ensure!(
                    elems == vb.size_elems,
                    "internal: {}{} block {:?} ({} elems) disagrees with Table 2 size {}",
                    t,
                    vb.ordinal,
                    dims4,
                    elems,
                    vb.size_elems
                );
                let level = plan
                    .buffers
                    .iter()
                    .find(|b| b.tensor == t && b.ordinal == vb.ordinal)
                    .map(|b| b.level.clone())
                    .ok_or_else(|| {
                        anyhow!(
                            "plan has no placement for {}{} — plan and string disagree",
                            t,
                            vb.ordinal
                        )
                    })?;
                by_pos[vb.created_at].push((t, chains[ci].len()));
                chains[ci].push(Block {
                    tensor: t,
                    ordinal: vb.ordinal,
                    level,
                    dims4,
                    origin: [0; 4],
                    data: vec![0.0; elems as usize],
                    fill_events: 0,
                    fill_elems: 0,
                    writeback_elems: 0,
                });
            }
        }
        let [input_chain, kernel_chain, output_chain] = chains;

        Ok(Interp {
            levels,
            by_pos,
            input_chain,
            kernel_chain,
            output_chain,
            dram_in: &inputs.input,
            dram_w: &inputs.weights,
            dram_out: vec![0.0; d.output_elems() as usize],
            in_dims: [d.b, d.c, d.y + d.fh - 1, d.x + d.fw - 1],
            w_dims: [d.k, d.c, d.fh, d.fw],
            out_dims: [d.b, d.k, d.y, d.x],
            dram: DramCounters::default(),
            macs_done: 0,
        })
    }

    fn run(&mut self) {
        self.subtree(self.levels.len(), [0u64; 7]);
    }

    /// Execute the sub-nest of the innermost `p` loop levels with the
    /// enclosing loops fixed at the offsets in `off`. On entry, buffers
    /// created by loop `p - 1` are (re)filled; on exit, output buffers
    /// created there write their partials back — the model's "refill on
    /// every enclosing iteration" semantics.
    fn subtree(&mut self, p: usize, off: [u64; 7]) {
        if p == 0 {
            self.mac(&off);
            return;
        }
        let pos = p - 1;
        let nbufs = self.by_pos[pos].len();
        for bi in 0..nbufs {
            let (t, i) = self.by_pos[pos][bi];
            self.fill(t, i, &off);
        }
        let (dim, trip, stride) = {
            let l = &self.levels[pos];
            (l.dim as usize, l.trip, l.stride)
        };
        let base = off[dim];
        let mut inner = off;
        for it in 0..trip {
            inner[dim] = base + it * stride;
            self.subtree(pos, inner);
        }
        for bi in 0..nbufs {
            let (t, i) = self.by_pos[pos][bi];
            if t == Tensor::Output {
                self.writeback(i);
            }
        }
    }

    /// (Re)fill buffer `i` of tensor `t`'s chain from its parent (the
    /// next-outer buffer of the same tensor, or the DRAM tensor). For
    /// output buffers this loads the current partial sums, so
    /// accumulation continues exactly where it left off.
    fn fill(&mut self, t: Tensor, i: usize, off: &[u64; 7]) {
        let origin = block_origin(t, off);
        match t {
            Tensor::Input => fill_chain(
                &mut self.input_chain,
                i,
                origin,
                self.dram_in,
                &self.in_dims,
                &mut self.dram.input_loads,
            ),
            Tensor::Kernel => fill_chain(
                &mut self.kernel_chain,
                i,
                origin,
                self.dram_w,
                &self.w_dims,
                &mut self.dram.kernel_loads,
            ),
            Tensor::Output => fill_chain(
                &mut self.output_chain,
                i,
                origin,
                &self.dram_out,
                &self.out_dims,
                &mut self.dram.output_loads,
            ),
        }
    }

    /// Write output buffer `i`'s partials back to its parent.
    fn writeback(&mut self, i: usize) {
        let (child, parent) = self.output_chain.split_at_mut(i + 1);
        let b = &mut child[i];
        let n = match parent.first_mut() {
            Some(par) => copy_region(
                &b.data, &b.dims4, &b.origin, &mut par.data, &par.dims4, &par.origin, &b.dims4,
                &b.origin,
            ),
            None => {
                let n = copy_region(
                    &b.data,
                    &b.dims4,
                    &b.origin,
                    &mut self.dram_out,
                    &self.out_dims,
                    &[0; 4],
                    &b.dims4,
                    &b.origin,
                );
                self.dram.output_stores += n;
                n
            }
        };
        b.writeback_elems += n;
    }

    /// One multiply-accumulate at the innermost point: operands come
    /// from each tensor's innermost buffer, or straight from DRAM when
    /// the blocking creates none (e.g. kernels in an FC layer with
    /// B = 1 — the paper's no-reuse case).
    #[inline]
    fn mac(&mut self, off: &[u64; 7]) {
        let o = |d: Dim| off[d as usize];
        let gi = [
            o(Dim::B),
            o(Dim::C),
            o(Dim::Y) + o(Dim::Fh),
            o(Dim::X) + o(Dim::Fw),
        ];
        let gw = [o(Dim::K), o(Dim::C), o(Dim::Fh), o(Dim::Fw)];
        let go = [o(Dim::B), o(Dim::K), o(Dim::Y), o(Dim::X)];
        let iv = match self.input_chain.first() {
            Some(b) => b.data[idx4(&b.dims4, &b.origin, &gi)],
            None => self.dram_in[idx4(&self.in_dims, &[0; 4], &gi)],
        };
        let wv = match self.kernel_chain.first() {
            Some(b) => b.data[idx4(&b.dims4, &b.origin, &gw)],
            None => self.dram_w[idx4(&self.w_dims, &[0; 4], &gw)],
        };
        match self.output_chain.first_mut() {
            Some(b) => {
                let i = idx4(&b.dims4, &b.origin, &go);
                b.data[i] += iv * wv;
            }
            None => {
                let i = idx4(&self.out_dims, &[0; 4], &go);
                self.dram_out[i] += iv * wv;
            }
        }
        self.macs_done += 1;
    }

    fn finish(self, d: &crate::model::dims::LayerDims) -> Result<ConvOutput> {
        ensure!(
            self.macs_done == d.macs(),
            "internal: executed {} MACs, layer has {}",
            self.macs_done,
            d.macs()
        );
        let level_of = |chain: &[Block]| {
            chain
                .first()
                .map(|b| b.level.clone())
                .unwrap_or_else(|| "DRAM".to_string())
        };
        let operand = OperandCounters {
            input_reads: self.macs_done,
            kernel_reads: self.macs_done,
            output_accesses: 2 * self.macs_done,
            input_level: level_of(&self.input_chain),
            kernel_level: level_of(&self.kernel_chain),
            output_level: level_of(&self.output_chain),
        };
        let mut buffers = Vec::new();
        for chain in [&self.input_chain, &self.kernel_chain, &self.output_chain] {
            for b in chain {
                buffers.push(BufferCounters {
                    tensor: b.tensor,
                    ordinal: b.ordinal,
                    level: b.level.clone(),
                    size_elems: b.dims4.iter().product(),
                    fill_events: b.fill_events,
                    fill_elems: b.fill_elems,
                    writeback_elems: b.writeback_elems,
                });
            }
        }
        Ok(ConvOutput {
            output: self.dram_out,
            counters: AccessCounters {
                backend: "blocked".to_string(),
                macs: self.macs_done,
                buffers,
                dram: self.dram,
                operand,
            },
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::naive_conv::conv_valid;
    use crate::model::dims::LayerDims;
    use crate::model::string::BlockingString;
    use crate::plan::{Planner, Provenance, Target};

    fn manual_plan(d: LayerDims, s: &str) -> BlockingPlan {
        let string = BlockingString::parse(s).unwrap().with_window(&d);
        BlockingPlan::evaluate(
            "t",
            d,
            string,
            Provenance::external(
                Target::Bespoke {
                    budget_bytes: 8 << 20,
                },
                "manual",
            ),
        )
        .unwrap()
    }

    fn naive_of(d: &LayerDims, inputs: &ConvInputs) -> Vec<f32> {
        let (h, w) = ((d.y + d.fh - 1) as usize, (d.x + d.fw - 1) as usize);
        let image = d.c as usize * h * w;
        let mut out = Vec::new();
        for b in 0..d.b as usize {
            out.extend(conv_valid(
                &inputs.input[b * image..(b + 1) * image],
                (d.c as usize, h, w),
                &inputs.weights,
                (d.k as usize, d.c as usize, d.fh as usize, d.fw as usize),
            ));
        }
        out
    }

    fn assert_close(a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            let rel = (x - y).abs() / x.abs().max(y.abs()).max(1.0);
            assert!(rel < 1e-4, "elem {}: {} vs {} (rel {})", i, x, y, rel);
        }
    }

    #[test]
    fn deep_blocking_matches_oracle() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        let inputs = ConvInputs::synthetic(d, 3);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
    }

    #[test]
    fn single_level_blocking_matches_oracle() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        for s in [
            "Fw Fh X0=8 Y0=8 C0=4 K0=2 K1=4",
            "Fw Fh X0=2 Y0=2 C0=4 K0=4 X1=8 Y1=8",
            "Fw Fh C0=4 K0=4 X0=8 Y0=8",
        ] {
            let plan = manual_plan(d, s);
            let inputs = ConvInputs::synthetic(d, 9);
            let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
            assert_close(&got.output, &naive_of(&d, &inputs));
        }
    }

    #[test]
    fn fc_with_batch_matches_oracle() {
        let d = LayerDims::fc(16, 8, 4);
        let plan = manual_plan(d, "Fw Fh C0=4 K0=8 B0=4 C1=16");
        let inputs = ConvInputs::synthetic(d, 1);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
    }

    #[test]
    fn fill_counts_match_the_interpreter_oracle() {
        // `model::validate::simulate` is the existing fill-count oracle;
        // the executing backend must agree with it buffer for buffer.
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        let inputs = ConvInputs::synthetic(d, 2);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        let bufs = allocate(&plan.string, &d);
        let sims = crate::model::validate::simulate(&plan.string, &d, &bufs);
        for sim in sims {
            let m = got
                .counters
                .buffers
                .iter()
                .find(|b| b.tensor == sim.tensor && b.ordinal == sim.ordinal)
                .unwrap();
            assert_eq!(m.fill_events, sim.model_fills, "{}{}", sim.tensor, sim.ordinal);
        }
    }

    #[test]
    fn output_partials_survive_eviction() {
        // C split above a K loop forces the output block to round-trip
        // through its parent mid-accumulation; numerics must be exact.
        let d = LayerDims::conv(4, 4, 8, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh X0=4 Y0=4 C0=2 K0=4 C1=8");
        let inputs = ConvInputs::synthetic(d, 4);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
        // the outermost OB really did write back more than once
        let ob = got
            .counters
            .chain(Tensor::Output)
            .last()
            .cloned()
            .cloned();
        let ob = ob.unwrap();
        assert!(ob.fill_events >= 1);
    }

    #[test]
    fn planned_layer_matches_oracle() {
        let d = LayerDims::conv(16, 16, 8, 8, 3, 3);
        let plan = Planner::for_named("p", d).levels(2).plan().unwrap();
        let inputs = ConvInputs::synthetic(d, 8);
        let got = BlockedCpuBackend.execute(&plan, &inputs).unwrap();
        assert_close(&got.output, &naive_of(&d, &inputs));
        // counters carry the plan's placement labels
        for b in &got.counters.buffers {
            assert!(
                plan.buffers
                    .iter()
                    .any(|pb| pb.tensor == b.tensor && pb.ordinal == b.ordinal && pb.level == b.level),
                "no placement for {}{} at {}",
                b.tensor,
                b.ordinal,
                b.level
            );
        }
    }

    #[test]
    fn dims_mismatch_is_an_error() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "Fw Fh C0=4 K0=4 X0=8 Y0=8");
        let other = ConvInputs::synthetic(LayerDims::conv(6, 6, 4, 4, 3, 3), 0);
        assert!(BlockedCpuBackend.execute(&plan, &other).is_err());
    }

    #[test]
    fn hoisted_window_strings_are_rejected() {
        // Table 2 sizes buffers under a hoisted window loop without the
        // swept window extent, so they are not executable as-is; the
        // backend must refuse rather than read out of block.
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = manual_plan(d, "X0=2 Fw Fh X1=8 Y0=8 C0=4 K0=4");
        let inputs = ConvInputs::synthetic(d, 1);
        let err = BlockedCpuBackend.execute(&plan, &inputs).unwrap_err();
        assert!(err.to_string().contains("hoisted"), "{}", err);
        // ...but window dims of extent 1 may sit anywhere (FC layers).
        let fc = LayerDims::fc(16, 8, 1);
        let fc_plan = manual_plan(fc, "C0=4 K0=8 C1=16 Fw Fh");
        assert!(BlockedCpuBackend
            .execute(&fc_plan, &ConvInputs::synthetic(fc, 2))
            .is_ok());
    }
}
