//! The tiled CPU fast path: the blocked loop nest with a compiled
//! level-0 tile kernel.
//!
//! [`TiledCpuBackend`] shares every buffer/fill mechanism with the
//! [`super::BlockedCpuBackend`] interpreter (both drive
//! [`super::nest::Nest`]), but stops the walker at the **level-0 tile
//! boundary** — the string position of the first repeated split — and
//! executes the whole innermost tile through one compiled kernel
//! instead of `tile_macs` interpreted recursion steps:
//!
//! * the `Fw x Fh` window runs as tight inner loops over contiguous
//!   input rows (LLVM fully unrolls them at the Table 4 window sizes);
//! * the `K0` output-channel block is processed in lane chunks of
//!   [`LANES`] with a per-chunk weight repack into `k`-contiguous
//!   layout, so the innermost statement is a broadcast-multiply-add
//!   over a fixed-width `f32` array — the portable shape the
//!   autovectorizer lifts to SIMD (no unstable intrinsics, no
//!   target-specific code);
//! * ragged tiles (a `K0` that is not a multiple of [`LANES`], odd
//!   `X0`) are handled by zero-padding the repacked weight lanes, so
//!   the hot loop stays branch-free.
//!
//! Table 2 buffers created *inside* the tile (the level-0 `IB0`/`KB0`/
//! `OB0`) are never materialized: the kernel reads operands from the
//! innermost *materialized* buffer of each tensor (or DRAM), and the
//! in-tile buffers' `AccessCounters` are derived analytically in
//! [`super::nest::Nest`] — the exact trip-count products the per-MAC
//! interpreter measures — so measured == predicted stays an exact
//! invariant (`rust/tests/backend.rs` pins it for this backend too).
//!
//! The weight repack comes in two flavours ([`TilePack`]): a per-nest
//! mutable cache keyed on the kernel view's fill generation (the
//! general case — kernel blocks change content as outer loops refill
//! them), and a **shared read-only prepack** of the whole weight tensor
//! ([`SharedPack`]) used when the plan materializes no kernel buffer
//! outside the tile — the kernel view is then the immutable DRAM
//! tensor, so [`super::ParallelTiledBackend`] packs once and every
//! shard worker reads the same blocks.
//!
//! The serial tiled path is one dispatch default for
//! `plan.execute(..)` (single worker thread) and the execution engine
//! under the parallel backend (multiple workers); `cnnblk bench`
//! measures the resulting MAC/s against the interpreter and the naive
//! nest.

use super::nest::{Nest, NestShard};
use super::{Backend, ConvInputs, ConvOutput, ExecLimits};
use crate::model::dims::{Dim, LayerDims};
use crate::model::string::BlockingString;
use crate::plan::BlockingPlan;
use anyhow::Result;
use std::sync::Arc;

/// f32 lanes the tile kernel processes per output-channel chunk. Eight
/// lanes map onto one AVX2 register / two NEON registers; the kernel is
/// written as plain array arithmetic so the autovectorizer picks
/// whatever the target offers.
pub const LANES: usize = 8;

/// Tiled loop-nest backend (see module docs).
#[derive(Debug, Clone, Copy, Default)]
pub struct TiledCpuBackend;

/// The string position where the level-0 tile ends: the first level that
/// is a *second* split of some dim. Everything below it (the window
/// loops plus the first split of each dim) is the tile the compiled
/// kernel executes; everything at or above it is walked by the shared
/// nest machinery. Returns `len()` when no dim is split twice — the
/// whole layer is then one tile.
pub(super) fn tile_boundary(s: &BlockingString) -> usize {
    let mut seen = [false; 7];
    for (i, l) in s.levels.iter().enumerate() {
        let d = l.dim as usize;
        if !matches!(l.dim, Dim::Fw | Dim::Fh) && seen[d] {
            return i;
        }
        seen[d] = true;
    }
    s.len()
}

/// Level-0 tile extents, in problem coordinates.
pub(super) struct Tile {
    b: usize,
    x: usize,
    y: usize,
    c: usize,
    k: usize,
    fw: usize,
    fh: usize,
}

impl Tile {
    /// The tile extents a plan's string implies below `boundary`.
    pub(super) fn of(plan: &BlockingPlan, boundary: usize) -> Tile {
        let cov = plan.string.covered_below(boundary);
        let g = |d: Dim| cov[d as usize] as usize;
        Tile {
            b: g(Dim::B),
            x: g(Dim::X),
            y: g(Dim::Y),
            c: g(Dim::C),
            k: g(Dim::K),
            // Window dims of extent 1 may be omitted from the string
            // (FC layers); the tile always spans the full window.
            fw: plan.dims.fw as usize,
            fh: plan.dims.fh as usize,
        }
    }

    fn macs(&self) -> u64 {
        (self.b * self.x * self.y * self.c * self.k * self.fw * self.fh) as u64
    }

    /// K lane-chunks per tile.
    fn chunks(&self) -> usize {
        self.k.div_ceil(LANES)
    }

    /// Packed elements per chunk (`c * fh * fw * LANES`).
    fn chunk_len(&self) -> usize {
        self.c * self.fh * self.fw * LANES
    }
}

/// Cached `k`-contiguous weight repack for the tile kernel. Consecutive
/// tile invocations often execute against an unchanged kernel block
/// (spatial/batch loops directly above the tile boundary); the cache
/// skips the repack unless the kernel view's content generation (the
/// innermost kernel buffer's fill count) or the tile's C/K offsets
/// changed, so the repack cost is paid once per kernel refill instead
/// of once per tile.
pub(super) struct PackCache {
    /// (kernel-buffer fill generation, `off[C]`, `off[K]`) of `data`;
    /// `None` until the first pack.
    key: Option<(u64, u64, u64)>,
    /// Packed weights, `[k_chunk][c][fh][fw][lane]`, lanes zero-padded
    /// past a ragged final chunk.
    data: Vec<f32>,
}

/// A read-only repack of the *entire* weight tensor into per-tile
/// `k`-contiguous blocks, built once and shared across shard workers
/// (see [`prepack_dram_weights`]). Valid only when the kernel view the
/// tile kernel reads is the immutable DRAM tensor — i.e. the plan
/// materializes no kernel buffer outside the tile.
pub(super) struct SharedPack {
    /// Packed blocks, `[c_block][k_block][k_chunk][c][fh][fw][lane]`.
    data: Vec<f32>,
    /// Elements per `(c_block, k_block)` block.
    block_len: usize,
    /// Number of K-offset blocks (`K / tile.k`).
    k_blocks: usize,
}

impl SharedPack {
    fn block(&self, ci: usize, ki: usize) -> &[f32] {
        let at = (ci * self.k_blocks + ki) * self.block_len;
        &self.data[at..at + self.block_len]
    }
}

/// Where a tile execution gets its packed weights from.
pub(super) enum TilePack {
    /// Per-nest mutable cache, repacked whenever the kernel view's
    /// content or the tile offsets change (the general case).
    Cache(PackCache),
    /// Immutable whole-tensor prepack shared read-only across workers
    /// (kernel served straight from DRAM; contents never change).
    Shared(Arc<SharedPack>),
}

/// Repack one tile-sized kernel block `k`-contiguous into `dst`:
/// `dst[((c*Fh + r)*Fw + s)*LANES + l] = W[wk0 + k0 + l][wc0 + c][r][s]`
/// per chunk, zero-padding missing lanes so the hot loop stays
/// branch-free. `(w_s0, w_s1, w_sr)` are the source view's K/C/row
/// strides; `(wk0, wc0)` the view-local K/C base of the block.
#[allow(clippy::too_many_arguments)] // strides + offsets of a raw view
fn pack_block(
    dst: &mut [f32],
    t: &Tile,
    w_data: &[f32],
    w_s0: usize,
    w_s1: usize,
    w_sr: usize,
    wk0: usize,
    wc0: usize,
) {
    let (fw, fh) = (t.fw, t.fh);
    let chunk_len = t.chunk_len();
    for (chunk, k0) in (0..t.k).step_by(LANES).enumerate() {
        let lanes = LANES.min(t.k - k0);
        let cbase = chunk * chunk_len;
        for c in 0..t.c {
            for r in 0..fh {
                for s in 0..fw {
                    let at = cbase + ((c * fh + r) * fw + s) * LANES;
                    let src = (wc0 + c) * w_s1 + r * w_sr + s;
                    for (l, slot) in dst[at..at + LANES].iter_mut().enumerate() {
                        *slot = if l < lanes {
                            w_data[(wk0 + k0 + l) * w_s0 + src]
                        } else {
                            0.0
                        };
                    }
                }
            }
        }
    }
}

/// Build the shared read-only repack of the full DRAM weight tensor:
/// one `k`-contiguous block per (C-offset, K-offset) tile position.
/// Tile offsets at the boundary are always multiples of the tile
/// extents (covered ranges of one dim form a divisibility chain), so
/// block lookup in [`SharedPack::block`] is exact.
pub(super) fn prepack_dram_weights(d: &LayerDims, t: &Tile, weights: &[f32]) -> SharedPack {
    let block_len = t.chunks() * t.chunk_len();
    let c_blocks = (d.c as usize) / t.c;
    let k_blocks = (d.k as usize) / t.k;
    let w_s0 = (d.c * d.fh * d.fw) as usize;
    let w_s1 = (d.fh * d.fw) as usize;
    let w_sr = d.fw as usize;
    let mut data = vec![0f32; c_blocks * k_blocks * block_len];
    for ci in 0..c_blocks {
        for ki in 0..k_blocks {
            let at = (ci * k_blocks + ki) * block_len;
            pack_block(
                &mut data[at..at + block_len],
                t,
                weights,
                w_s0,
                w_s1,
                w_sr,
                ki * t.k,
                ci * t.c,
            );
        }
    }
    SharedPack {
        data,
        block_len,
        k_blocks,
    }
}

/// Run a plan through the tiled execution path: walk the nest down to
/// the level-0 tile boundary (optionally restricted to one grid cell's
/// iteration ranges — see [`NestShard`]; empty slice = whole layer) and
/// execute each tile through the compiled kernel. `label` names the
/// backend in the counter report; `shared_pack` supplies the read-only
/// weight prepack when the caller knows the kernel view is the
/// immutable DRAM tensor (ignored otherwise).
pub(super) fn execute_tiled(
    plan: &BlockingPlan,
    inputs: &ConvInputs,
    shards: &[NestShard],
    label: &'static str,
    shared_pack: Option<&Arc<SharedPack>>,
    limits: ExecLimits,
) -> Result<ConvOutput> {
    let boundary = tile_boundary(&plan.string);
    let tile = Tile::of(plan, boundary);
    // The per-tile weight repack is real allocation too; price it into
    // the nest's resource-guard check.
    let repack_bytes = (tile.chunks() as u64)
        .saturating_mul(tile.chunk_len() as u64)
        .saturating_mul(4);
    let mut nest = Nest::with_shards(plan, inputs, boundary, shards, limits, repack_bytes)?;
    let mut pack = match shared_pack {
        // The prepack is only sound while the kernel view is DRAM.
        Some(sp) if nest.kernel_chain.is_empty() => TilePack::Shared(Arc::clone(sp)),
        _ => TilePack::Cache(PackCache {
            key: None,
            data: vec![0f32; tile.chunks() * tile.chunk_len()],
        }),
    };
    nest.run(&mut |n, off| exec_tile(n, off, &tile, &mut pack));
    nest.finish(label)
}

impl Backend for TiledCpuBackend {
    fn name(&self) -> &'static str {
        "tiled"
    }

    fn execute_with(
        &self,
        plan: &BlockingPlan,
        inputs: &ConvInputs,
        limits: ExecLimits,
    ) -> Result<ConvOutput> {
        execute_tiled(plan, inputs, &[], "tiled", None, limits)
    }
}

/// Execute one level-0 tile at the global offsets in `off`, reading
/// operands from the innermost materialized buffer of each tensor (or
/// the DRAM tensor when a chain is empty or fully virtualized) and
/// accumulating into the innermost materialized output buffer.
fn exec_tile(n: &mut Nest<'_>, off: &[u64; 7], t: &Tile, pack: &mut TilePack) {
    let o = |d: Dim| off[d as usize] as usize;
    // Content generation of the kernel view: the innermost materialized
    // kernel buffer's fill count (bumped on every refill), or a constant
    // for the immutable DRAM tensor.
    let w_gen = n.kernel_chain.first().map(|b| b.fill_events).unwrap_or(0);
    // Source views: (data, extents, origin). Field-disjoint borrows of
    // the nest keep input/kernel shared while output is mutable.
    let (in_data, in_d, in_org): (&[f32], [u64; 4], [u64; 4]) = match n.input_chain.first() {
        Some(b) => (b.data.as_slice(), b.dims4, b.origin),
        None => (n.dram_in, n.in_dims, [0; 4]),
    };
    let (w_data, w_d, w_org): (&[f32], [u64; 4], [u64; 4]) = match n.kernel_chain.first() {
        Some(b) => (b.data.as_slice(), b.dims4, b.origin),
        None => (n.dram_w, n.w_dims, [0; 4]),
    };
    let (out_data, out_d, out_org): (&mut [f32], [u64; 4], [u64; 4]) =
        match n.output_chain.first_mut() {
            Some(b) => {
                let (dims4, origin) = (b.dims4, b.origin);
                (b.data.as_mut_slice(), dims4, origin)
            }
            None => (n.dram_out.as_mut_slice(), n.out_dims, [0; 4]),
        };

    // Local (block-relative) bases of the tile in each view. Window
    // offsets are always 0 here: window loops live inside the tile, and
    // materialized-buffer origins fold them the same way.
    let ib0 = o(Dim::B) - in_org[0] as usize;
    let ic0 = o(Dim::C) - in_org[1] as usize;
    let ih0 = o(Dim::Y) - in_org[2] as usize;
    let iw0 = o(Dim::X) - in_org[3] as usize;
    let wk0 = o(Dim::K) - w_org[0] as usize;
    let wc0 = o(Dim::C) - w_org[1] as usize;
    let ob0 = o(Dim::B) - out_org[0] as usize;
    let ok0 = o(Dim::K) - out_org[1] as usize;
    let oy0 = o(Dim::Y) - out_org[2] as usize;
    let ox0 = o(Dim::X) - out_org[3] as usize;

    // Row-major strides of each view.
    let in_s2 = in_d[3] as usize;
    let in_s1 = (in_d[2] * in_d[3]) as usize;
    let in_s0 = (in_d[1] * in_d[2] * in_d[3]) as usize;
    let w_s1 = (w_d[2] * w_d[3]) as usize;
    let w_s0 = (w_d[1] * w_d[2] * w_d[3]) as usize;
    let w_sr = w_d[3] as usize;
    let out_s2 = out_d[3] as usize;
    let out_s1 = (out_d[2] * out_d[3]) as usize;
    let out_s0 = (out_d[1] * out_d[2] * out_d[3]) as usize;

    let (fw, fh) = (t.fw, t.fh);
    let chunk_len = t.chunk_len();
    let packed: &[f32] = match pack {
        TilePack::Shared(sp) => {
            // Only sound while the kernel view really is the DRAM
            // tensor — `execute_tiled` guarantees it.
            debug_assert!(n.kernel_chain.is_empty(), "shared pack with live kernel buffer");
            sp.block(o(Dim::C) / t.c, o(Dim::K) / t.k)
        }
        TilePack::Cache(pc) => {
            // Repack the kernel tile k-contiguous, once per kernel-view
            // change.
            let key = (w_gen, off[Dim::C as usize], off[Dim::K as usize]);
            if pc.key != Some(key) {
                pack_block(&mut pc.data, t, w_data, w_s0, w_s1, w_sr, wk0, wc0);
                pc.key = Some(key);
            }
            pc.data.as_slice()
        }
    };
    for (chunk, k0) in (0..t.k).step_by(LANES).enumerate() {
        let lanes = LANES.min(t.k - k0);
        let wpack = &packed[chunk * chunk_len..(chunk + 1) * chunk_len];
        for b in 0..t.b {
            let ibase = (ib0 + b) * in_s0;
            let obase_b = (ob0 + b) * out_s0 + (ok0 + k0) * out_s1;
            for y in 0..t.y {
                for x in 0..t.x {
                    let obase = obase_b + (oy0 + y) * out_s2 + ox0 + x;
                    // Load the running partials for this output point.
                    let mut acc = [0f32; LANES];
                    for (l, a) in acc.iter_mut().take(lanes).enumerate() {
                        *a = out_data[obase + l * out_s1];
                    }
                    let mut wi = 0usize;
                    for c in 0..t.c {
                        let cbase = ibase + (ic0 + c) * in_s1;
                        for r in 0..fh {
                            let rbase = cbase + (ih0 + y + r) * in_s2 + iw0 + x;
                            let row = &in_data[rbase..rbase + fw];
                            for &iv in row {
                                let wrow = &wpack[wi * LANES..wi * LANES + LANES];
                                for (a, &wv) in acc.iter_mut().zip(wrow) {
                                    *a += iv * wv;
                                }
                                wi += 1;
                            }
                        }
                    }
                    for (l, a) in acc.iter().take(lanes).enumerate() {
                        out_data[obase + l * out_s1] = *a;
                    }
                }
            }
        }
    }
    n.macs_done += t.macs();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::dims::LayerDims;

    fn parse(d: &LayerDims, s: &str) -> BlockingString {
        let b = BlockingString::parse(s).unwrap().with_window(d);
        b.validate(d).unwrap();
        b
    }

    #[test]
    fn boundary_is_the_first_repeated_split() {
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let s = parse(&d, "Fw Fh X0=4 Y0=4 C0=2 K0=2 C1=4 K1=4 X1=8 Y1=8");
        assert_eq!(tile_boundary(&s), 6);
        // a fully single-level string is one big tile
        let s = parse(&d, "Fw Fh C0=4 K0=4 X0=8 Y0=8");
        assert_eq!(tile_boundary(&s), s.len());
        // a repeat before other dims' first split shrinks the tile
        let s = parse(&d, "Fw Fh X0=4 X1=8 Y0=8 C0=4 K0=4");
        assert_eq!(tile_boundary(&s), 3);
    }

    #[test]
    fn fc_boundary_skips_trailing_unit_windows() {
        let fc = LayerDims::fc(16, 8, 1);
        let s = parse(&fc, "C0=4 K0=8 C1=16 Fw Fh");
        assert_eq!(tile_boundary(&s), 2);
    }

    #[test]
    fn prepack_blocks_match_per_view_packing() {
        // The shared prepack must hold, block for block, exactly what
        // pack_block produces from the raw DRAM view at that offset.
        let d = LayerDims::conv(4, 4, 4, 6, 3, 3);
        let weights: Vec<f32> = (0..d.kernel_elems()).map(|i| i as f32).collect();
        let t = Tile {
            b: 1,
            x: 4,
            y: 4,
            c: 2,
            k: 3,
            fw: 3,
            fh: 3,
        };
        let sp = prepack_dram_weights(&d, &t, &weights);
        let block_len = t.chunks() * t.chunk_len();
        let mut want = vec![0f32; block_len];
        let (w_s0, w_s1, w_sr) = (36, 9, 3);
        // block (ci=1, ki=1): C offset 2, K offset 3
        pack_block(&mut want, &t, &weights, w_s0, w_s1, w_sr, 3, 2);
        assert_eq!(sp.block(1, 1), &want[..]);
        // ragged K0=3 zero-pads lanes 3..8 of the only chunk
        assert_eq!(t.chunks(), 1);
        for probe in sp.block(0, 0).chunks(LANES) {
            assert!(probe[3..].iter().all(|&v| v == 0.0));
        }
    }
}
