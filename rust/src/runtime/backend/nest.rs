//! Shared loop-nest machinery for the plan-executing CPU backends.
//!
//! Both the per-MAC interpreter ([`super::BlockedCpuBackend`]) and the
//! tiled fast path ([`super::TiledCpuBackend`]) execute a plan the same
//! way: walk the blocking string outermost→innermost, keep one real
//! `f32` buffer per *materialized* Table 2 virtual buffer, refill a
//! buffer from its parent (the next-outer buffer of the same tensor, or
//! DRAM) every time an enclosing loop iterates, and write output
//! partials back on loop exit — the model semantics `model::access`
//! charges analytically. This module owns that machinery ([`Nest`]):
//! buffer geometry, the `fill_chain`/`copy_region`/writeback transfers,
//! the recursive walker, and the counter bookkeeping.
//!
//! What differs per backend is the **leaf**: how far down the walker
//! recurses before handing control to compute. The interpreter walks
//! every level and executes one MAC per leaf (`boundary == 0`); the
//! tiled backend stops at the level-0 tile boundary and runs a compiled
//! kernel over the whole tile (`boundary == tile_boundary(..)`). Table 2
//! buffers created *inside* the leaf region are "virtualized": they are
//! never materialized (the kernel reads operands from the innermost
//! materialized buffer instead), and their fill/writeback counters are
//! derived analytically — the exact trip-count products the interpreter
//! would measure — so measured == predicted stays an exact invariant for
//! every backend driving a [`Nest`].

use super::{
    AccessCounters, BufferCounters, ConvInputs, ConvOutput, DramCounters, ExecLimits,
    OperandCounters,
};
use crate::model::buffers::{allocate, Tensor};
use crate::model::dims::Dim;
use crate::plan::BlockingPlan;
use anyhow::{anyhow, ensure, Result};

/// One real buffer backing a materialized Table 2 virtual buffer during
/// execution. (Its creation position lives in `Nest::by_pos`.)
pub(super) struct Block {
    pub(super) tensor: Tensor,
    pub(super) ordinal: usize,
    /// Physical level the plan placed it on (counter label only).
    pub(super) level: String,
    /// Block extents in the tensor's axis order (see `block_geometry`).
    pub(super) dims4: [u64; 4],
    /// Global origin of the currently-held block, same axis order.
    pub(super) origin: [u64; 4],
    pub(super) data: Vec<f32>,
    pub(super) fill_events: u64,
    pub(super) fill_elems: u64,
    pub(super) writeback_elems: u64,
}

/// One loop level of the nest, precomputed from the blocking string.
struct LoopLevel {
    dim: Dim,
    trip: u64,
    /// Step of the dim's global offset per iteration (covered extent of
    /// the dim strictly below this position).
    stride: u64,
}

/// Axis order per tensor, chosen to match the DRAM layouts so the DRAM
/// "parent" is just a block with full extents and origin zero:
/// input `(B, C, H, W)`, kernel `(K, C, Fh, Fw)`, output `(B, K, Y, X)`.
fn block_geometry(t: Tensor, cov: &[u64; 7]) -> [u64; 4] {
    let g = |d: Dim| cov[d as usize];
    match t {
        Tensor::Input => [
            g(Dim::B),
            g(Dim::C),
            g(Dim::Y) + g(Dim::Fh) - 1,
            g(Dim::X) + g(Dim::Fw) - 1,
        ],
        Tensor::Kernel => [g(Dim::K), g(Dim::C), g(Dim::Fh), g(Dim::Fw)],
        Tensor::Output => [g(Dim::B), g(Dim::K), g(Dim::Y), g(Dim::X)],
    }
}

/// Global block origin for a tensor given the enclosing-loop offsets.
/// Input rows/cols fold the window offset in (`h = y + fh`).
fn block_origin(t: Tensor, off: &[u64; 7]) -> [u64; 4] {
    let o = |d: Dim| off[d as usize];
    match t {
        Tensor::Input => [
            o(Dim::B),
            o(Dim::C),
            o(Dim::Y) + o(Dim::Fh),
            o(Dim::X) + o(Dim::Fw),
        ],
        Tensor::Kernel => [o(Dim::K), o(Dim::C), o(Dim::Fh), o(Dim::Fw)],
        Tensor::Output => [o(Dim::B), o(Dim::K), o(Dim::Y), o(Dim::X)],
    }
}

/// Flat index of global coordinate `g` inside an array of extents
/// `dims4` whose element [0,0,0,0] sits at global `origin`.
#[inline]
pub(super) fn idx4(dims4: &[u64; 4], origin: &[u64; 4], g: &[u64; 4]) -> usize {
    let l0 = g[0] - origin[0];
    let l1 = g[1] - origin[1];
    let l2 = g[2] - origin[2];
    let l3 = g[3] - origin[3];
    debug_assert!(
        l0 < dims4[0] && l1 < dims4[1] && l2 < dims4[2] && l3 < dims4[3],
        "coordinate {:?} outside block {:?}@{:?}",
        g,
        dims4,
        origin
    );
    (((l0 * dims4[1] + l1) * dims4[2] + l2) * dims4[3] + l3) as usize
}

/// Copy the whole `region`-sized block at global origin `gorg` from
/// `(src, sdims, sorg)` into `(dst, ddims, dorg)`; returns elements
/// moved. Rows (the last axis) are copied contiguously.
#[allow(clippy::too_many_arguments)] // (array, dims, origin) x2 + region
fn copy_region(
    src: &[f32],
    sdims: &[u64; 4],
    sorg: &[u64; 4],
    dst: &mut [f32],
    ddims: &[u64; 4],
    dorg: &[u64; 4],
    region: &[u64; 4],
    gorg: &[u64; 4],
) -> u64 {
    let w = region[3] as usize;
    for a0 in 0..region[0] {
        for a1 in 0..region[1] {
            for a2 in 0..region[2] {
                let g = [gorg[0] + a0, gorg[1] + a1, gorg[2] + a2, gorg[3]];
                let si = idx4(sdims, sorg, &g);
                let di = idx4(ddims, dorg, &g);
                dst[di..di + w].copy_from_slice(&src[si..si + w]);
            }
        }
    }
    region[0] * region[1] * region[2] * region[3]
}

/// Refill buffer `i` of `chain` at `origin`: copy its block from the
/// next-outer buffer, or from the DRAM-resident tensor (bumping that
/// tensor's DRAM-load counter) when `i` is the outermost.
fn fill_chain(
    chain: &mut [Block],
    i: usize,
    origin: [u64; 4],
    dram_src: &[f32],
    dram_dims: &[u64; 4],
    dram_loads: &mut u64,
) {
    let (child, parent) = chain.split_at_mut(i + 1);
    let b = &mut child[i];
    b.origin = origin;
    let n = match parent.first() {
        Some(par) => copy_region(
            &par.data, &par.dims4, &par.origin, &mut b.data, &b.dims4, &b.origin, &b.dims4,
            &b.origin,
        ),
        None => {
            let n = copy_region(
                dram_src, dram_dims, &[0; 4], &mut b.data, &b.dims4, &b.origin, &b.dims4,
                &b.origin,
            );
            *dram_loads += n;
            n
        }
    };
    b.fill_events += 1;
    b.fill_elems += n;
}

/// Restriction of one walked loop level to a contiguous sub-range of
/// its iterations — how [`super::ParallelTiledBackend`] splits a layer
/// into per-worker shard-grid cells. A nest may carry several
/// restrictions at once (one per grid axis, e.g. a K level and a Y
/// level), each on a *distinct* level at or above the leaf boundary;
/// every other level runs in full. Counters for buffers whose fills
/// ride a restricted loop scale naturally (the walker simply executes
/// fewer iterations); counters for buffers created at or above a
/// restricted level repeat across the cells that share its range and
/// are de-duplicated at merge time by the parallel backend.
#[derive(Debug, Clone, Copy)]
pub(super) struct NestShard {
    /// String position of the restricted loop level.
    pub(super) pos: usize,
    /// First iteration (inclusive) of that level to execute.
    pub(super) start: u64,
    /// Last iteration (exclusive) of that level to execute.
    pub(super) end: u64,
}

/// A live loop nest executing one plan: the walker state, the
/// materialized buffer chains, the DRAM-resident tensors, and every
/// counter. Backends drive it via [`Nest::run`] with a leaf callback and
/// collect the result with [`Nest::finish`].
pub(super) struct Nest<'a> {
    levels: Vec<LoopLevel>,
    /// Iteration-range restrictions (one per grid axis), if sharded.
    shards: Vec<NestShard>,
    /// MACs this (possibly sharded) nest is expected to execute.
    expected_macs: u64,
    /// Materialized buffers created at each string position, as
    /// (tensor, index into that tensor's materialized chain).
    by_pos: Vec<Vec<(Tensor, usize)>>,
    /// Positions below `boundary` are executed by the leaf; buffers
    /// created there are virtualized (analytic counters, no storage).
    boundary: usize,
    pub(super) input_chain: Vec<Block>,
    pub(super) kernel_chain: Vec<Block>,
    pub(super) output_chain: Vec<Block>,
    pub(super) dram_in: &'a [f32],
    pub(super) dram_w: &'a [f32],
    pub(super) dram_out: Vec<f32>,
    pub(super) in_dims: [u64; 4],
    pub(super) w_dims: [u64; 4],
    pub(super) out_dims: [u64; 4],
    pub(super) dram: DramCounters,
    pub(super) macs_done: u64,
    /// Analytically-derived counters for virtualized buffers, per tensor
    /// in `Tensor::ALL` order, innermost first.
    virtualized: [Vec<BufferCounters>; 3],
    /// Level label serving each tensor's MAC-rate operand stream (the
    /// plan's innermost buffer, materialized or not; DRAM when none).
    operand_levels: [String; 3],
}

impl<'a> Nest<'a> {
    /// Validate `plan` against `inputs` and set up the nest. Buffers
    /// created at string positions `< boundary` are virtualized: their
    /// fill/writeback counters are the exact trip-count products the
    /// interpreter would measure, charged up front; the leaf is expected
    /// to execute those loops itself. `boundary == 0` materializes
    /// everything (the interpreter configuration). The nest refuses
    /// with a typed [`super::ExecError`] before allocating anything
    /// when the working set or MAC count exceeds `limits`.
    pub(super) fn new(
        plan: &BlockingPlan,
        inputs: &'a ConvInputs,
        boundary: usize,
        limits: ExecLimits,
    ) -> Result<Nest<'a>> {
        Nest::with_shards(plan, inputs, boundary, &[], limits, 0)
    }

    /// [`Nest::new`] with iteration-range restrictions of zero or more
    /// *distinct* walked levels (see [`NestShard`]) — one per grid axis.
    /// Virtualized-buffer counters and their DRAM terminals are derived
    /// from the *effective* trip counts, so a cell's analytic counters
    /// are exactly its share of the whole layer's. `extra_bytes` is
    /// working-set allocation the *caller* will add on top of the
    /// nest's own buffers (the tiled kernel's weight repack), priced
    /// into the same `limits` check.
    pub(super) fn with_shards(
        plan: &BlockingPlan,
        inputs: &'a ConvInputs,
        boundary: usize,
        shards: &[NestShard],
        limits: ExecLimits,
        extra_bytes: u64,
    ) -> Result<Nest<'a>> {
        let d = plan.dims;
        ensure!(
            inputs.dims == d,
            "inputs are for {} but the plan is for {}",
            inputs.dims,
            d
        );
        plan.string
            .validate(&d)
            .map_err(|e| anyhow!("plan string '{}' invalid for {}: {}", plan.string, d, e))?;
        ensure!(
            inputs.input.len() as u64 == d.input_elems()
                && inputs.weights.len() as u64 == d.kernel_elems(),
            "input/weight tensors do not match {}",
            d
        );
        let s = &plan.string;
        let n = s.len();
        ensure!(boundary <= n, "internal: boundary {} beyond string", boundary);

        // Table 2 sizes a buffer created at-or-below a hoisted window
        // loop *without* the window extent that loop sweeps (the model
        // charges the re-reads through the refetch-rate chain instead),
        // so such a buffer physically cannot serve the window's reads —
        // executing it would index outside the block. The optimizer
        // never hoists Fw/Fh (they stay innermost); reject the rare
        // hand-written string that does.
        let first_nonwindow = s
            .levels
            .iter()
            .position(|l| !matches!(l.dim, Dim::Fw | Dim::Fh))
            .unwrap_or(n);
        if let Some(hoisted) = s.levels[first_nonwindow.min(n)..]
            .iter()
            .find(|l| matches!(l.dim, Dim::Fw | Dim::Fh) && l.range > 1)
        {
            return Err(anyhow!(
                "backend cannot execute '{}': window loop {} is hoisted \
                 above other loops (Fw/Fh must be innermost)",
                s,
                hoisted.dim
            ));
        }

        let mut levels = Vec::with_capacity(n);
        for i in 0..n {
            let dim = s.levels[i].dim;
            let stride = s.covered_below(i)[dim as usize];
            levels.push(LoopLevel {
                dim,
                trip: s.trip(i),
                stride,
            });
        }
        let mut expected_macs = d.macs();
        for (i, sh) in shards.iter().enumerate() {
            ensure!(
                sh.pos >= boundary && sh.pos < n,
                "internal: shard level {} outside walked range [{}, {})",
                sh.pos,
                boundary,
                n
            );
            ensure!(
                sh.start < sh.end && sh.end <= levels[sh.pos].trip,
                "internal: shard range {}..{} invalid for trip {}",
                sh.start,
                sh.end,
                levels[sh.pos].trip
            );
            ensure!(
                shards[..i].iter().all(|prev| prev.pos != sh.pos),
                "internal: two shard restrictions on level {}",
                sh.pos
            );
            // Every trip is a factor of macs() on a validated string, so
            // this division is exact, and distinct positions make the
            // per-restriction factors independent.
            expected_macs = expected_macs / levels[sh.pos].trip * (sh.end - sh.start);
        }
        // trips_above[p] = product of *effective* trip counts at
        // positions >= p — the fill count of a buffer created at
        // position p - 1. A sharded level contributes only the
        // iterations this nest will actually run.
        let eff = |p: usize| match shards.iter().find(|sh| sh.pos == p) {
            Some(sh) => sh.end - sh.start,
            None => levels[p].trip,
        };
        let mut trips_above = vec![1u64; n + 1];
        for p in (0..n).rev() {
            trips_above[p] = trips_above[p + 1] * eff(p);
        }

        let bufs = allocate(s, &d);
        // Resource guard: price the working set this nest is about to
        // allocate — one real f32 buffer per materialized Table 2
        // virtual buffer, the DRAM-resident output tensor, plus the
        // caller's `extra_bytes` — and refuse with a typed ExecError
        // before allocating any of it. Sharded cells check the whole
        // layer's MAC count, so a limit admits or refuses a plan
        // identically at every worker width.
        let mut need_bytes = d
            .output_elems()
            .saturating_mul(4)
            .saturating_add(extra_bytes);
        for t in Tensor::ALL {
            for vb in bufs.of(t) {
                if vb.created_at >= boundary {
                    need_bytes = need_bytes.saturating_add(vb.size_elems.saturating_mul(4));
                }
            }
        }
        limits.check(d.macs(), need_bytes).map_err(anyhow::Error::new)?;
        let mut by_pos: Vec<Vec<(Tensor, usize)>> = vec![Vec::new(); n];
        let mut chains: [Vec<Block>; 3] = [Vec::new(), Vec::new(), Vec::new()];
        let mut virtualized: [Vec<BufferCounters>; 3] = Default::default();
        let mut dram = DramCounters::default();
        let mut operand_levels = [
            "DRAM".to_string(),
            "DRAM".to_string(),
            "DRAM".to_string(),
        ];
        for (ci, t) in Tensor::ALL.into_iter().enumerate() {
            let chain_len = bufs.of(t).len();
            for vb in bufs.of(t) {
                let cov = s.covered_below(vb.created_at);
                let dims4 = block_geometry(t, &cov);
                let elems = dims4.iter().product::<u64>();
                ensure!(
                    elems == vb.size_elems,
                    "internal: {}{} block {:?} ({} elems) disagrees with Table 2 size {}",
                    t,
                    vb.ordinal,
                    dims4,
                    elems,
                    vb.size_elems
                );
                let level = plan
                    .buffers
                    .iter()
                    .find(|b| b.tensor == t && b.ordinal == vb.ordinal)
                    .map(|b| b.level.clone())
                    .ok_or_else(|| {
                        anyhow!(
                            "plan has no placement for {}{} — plan and string disagree",
                            t,
                            vb.ordinal
                        )
                    })?;
                if vb.ordinal == 0 {
                    operand_levels[ci] = level.clone();
                }
                if vb.created_at < boundary {
                    // Virtualized: the leaf executes the loops that would
                    // fill this buffer. Its measured counters are the
                    // trip-count products the interpreter realizes — one
                    // fill per iteration of every enclosing loop, each
                    // fill paired with a writeback for output partials.
                    let fill_events = trips_above[vb.created_at + 1];
                    let fill_elems = fill_events * vb.size_elems;
                    let writeback_elems = if t == Tensor::Output { fill_elems } else { 0 };
                    if vb.ordinal + 1 == chain_len {
                        // Outermost buffer of its chain: its fills (and
                        // writebacks) are DRAM traffic.
                        match t {
                            Tensor::Input => dram.input_loads += fill_elems,
                            Tensor::Kernel => dram.kernel_loads += fill_elems,
                            Tensor::Output => {
                                dram.output_loads += fill_elems;
                                dram.output_stores += writeback_elems;
                            }
                        }
                    }
                    virtualized[ci].push(BufferCounters {
                        tensor: t,
                        ordinal: vb.ordinal,
                        level,
                        size_elems: vb.size_elems,
                        fill_events,
                        fill_elems,
                        writeback_elems,
                    });
                } else {
                    by_pos[vb.created_at].push((t, chains[ci].len()));
                    chains[ci].push(Block {
                        tensor: t,
                        ordinal: vb.ordinal,
                        level,
                        dims4,
                        origin: [0; 4],
                        data: vec![0.0; elems as usize],
                        fill_events: 0,
                        fill_elems: 0,
                        writeback_elems: 0,
                    });
                }
            }
        }
        let [input_chain, kernel_chain, output_chain] = chains;

        Ok(Nest {
            levels,
            shards: shards.to_vec(),
            expected_macs,
            by_pos,
            boundary,
            input_chain,
            kernel_chain,
            output_chain,
            dram_in: &inputs.input,
            dram_w: &inputs.weights,
            dram_out: vec![0.0; d.output_elems() as usize],
            in_dims: [d.b, d.c, d.y + d.fh - 1, d.x + d.fw - 1],
            w_dims: [d.k, d.c, d.fh, d.fw],
            out_dims: [d.b, d.k, d.y, d.x],
            dram,
            macs_done: 0,
            virtualized,
            operand_levels,
        })
    }

    /// Walk the nest from the outermost loop down to the boundary,
    /// refilling/writing back materialized buffers per model semantics,
    /// and invoke `leaf` once per boundary-level iteration point.
    pub(super) fn run<F>(&mut self, leaf: &mut F)
    where
        F: FnMut(&mut Nest<'a>, &[u64; 7]),
    {
        self.subtree(self.levels.len(), [0u64; 7], leaf);
    }

    /// Execute the sub-nest of the innermost `p` loop levels with the
    /// enclosing loops fixed at the offsets in `off`. On entry, buffers
    /// created by loop `p - 1` are (re)filled; on exit, output buffers
    /// created there write their partials back — the model's "refill on
    /// every enclosing iteration" semantics.
    fn subtree<F>(&mut self, p: usize, off: [u64; 7], leaf: &mut F)
    where
        F: FnMut(&mut Nest<'a>, &[u64; 7]),
    {
        if p == self.boundary {
            leaf(self, &off);
            return;
        }
        let pos = p - 1;
        let nbufs = self.by_pos[pos].len();
        for bi in 0..nbufs {
            let (t, i) = self.by_pos[pos][bi];
            self.fill(t, i, &off);
        }
        let (dim, trip, stride) = {
            let l = &self.levels[pos];
            (l.dim as usize, l.trip, l.stride)
        };
        // A sharded level runs only its assigned iteration sub-range;
        // every other level runs in full.
        let (it0, it1) = match self.shards.iter().find(|sh| sh.pos == pos) {
            Some(sh) => (sh.start, sh.end),
            None => (0, trip),
        };
        let base = off[dim];
        let mut inner = off;
        for it in it0..it1 {
            inner[dim] = base + it * stride;
            self.subtree(pos, inner, leaf);
        }
        for bi in 0..nbufs {
            let (t, i) = self.by_pos[pos][bi];
            if t == Tensor::Output {
                self.writeback(i);
            }
        }
    }

    /// (Re)fill buffer `i` of tensor `t`'s chain from its parent (the
    /// next-outer buffer of the same tensor, or the DRAM tensor). For
    /// output buffers this loads the current partial sums, so
    /// accumulation continues exactly where it left off.
    fn fill(&mut self, t: Tensor, i: usize, off: &[u64; 7]) {
        let origin = block_origin(t, off);
        match t {
            Tensor::Input => fill_chain(
                &mut self.input_chain,
                i,
                origin,
                self.dram_in,
                &self.in_dims,
                &mut self.dram.input_loads,
            ),
            Tensor::Kernel => fill_chain(
                &mut self.kernel_chain,
                i,
                origin,
                self.dram_w,
                &self.w_dims,
                &mut self.dram.kernel_loads,
            ),
            Tensor::Output => fill_chain(
                &mut self.output_chain,
                i,
                origin,
                &self.dram_out,
                &self.out_dims,
                &mut self.dram.output_loads,
            ),
        }
    }

    /// Write output buffer `i`'s partials back to its parent.
    fn writeback(&mut self, i: usize) {
        let (child, parent) = self.output_chain.split_at_mut(i + 1);
        let b = &mut child[i];
        let n = match parent.first_mut() {
            Some(par) => copy_region(
                &b.data, &b.dims4, &b.origin, &mut par.data, &par.dims4, &par.origin, &b.dims4,
                &b.origin,
            ),
            None => {
                let n = copy_region(
                    &b.data,
                    &b.dims4,
                    &b.origin,
                    &mut self.dram_out,
                    &self.out_dims,
                    &[0; 4],
                    &b.dims4,
                    &b.origin,
                );
                self.dram.output_stores += n;
                n
            }
        };
        b.writeback_elems += n;
    }

    /// One multiply-accumulate at an innermost point: operands come
    /// from each tensor's innermost buffer, or straight from DRAM when
    /// the blocking creates none (e.g. kernels in an FC layer with
    /// B = 1 — the paper's no-reuse case). The interpreter's leaf.
    #[inline]
    pub(super) fn mac_at(&mut self, off: &[u64; 7]) {
        let o = |d: Dim| off[d as usize];
        let gi = [
            o(Dim::B),
            o(Dim::C),
            o(Dim::Y) + o(Dim::Fh),
            o(Dim::X) + o(Dim::Fw),
        ];
        let gw = [o(Dim::K), o(Dim::C), o(Dim::Fh), o(Dim::Fw)];
        let go = [o(Dim::B), o(Dim::K), o(Dim::Y), o(Dim::X)];
        let iv = match self.input_chain.first() {
            Some(b) => b.data[idx4(&b.dims4, &b.origin, &gi)],
            None => self.dram_in[idx4(&self.in_dims, &[0; 4], &gi)],
        };
        let wv = match self.kernel_chain.first() {
            Some(b) => b.data[idx4(&b.dims4, &b.origin, &gw)],
            None => self.dram_w[idx4(&self.w_dims, &[0; 4], &gw)],
        };
        match self.output_chain.first_mut() {
            Some(b) => {
                let i = idx4(&b.dims4, &b.origin, &go);
                b.data[i] += iv * wv;
            }
            None => {
                let i = idx4(&self.out_dims, &[0; 4], &go);
                self.dram_out[i] += iv * wv;
            }
        }
        self.macs_done += 1;
    }

    /// Collect the output tensor and the full access report: measured
    /// counters from the materialized chains merged (innermost first)
    /// with the analytic counters of any virtualized buffers.
    pub(super) fn finish(self, backend: &str) -> Result<ConvOutput> {
        ensure!(
            self.macs_done == self.expected_macs,
            "internal: executed {} MACs, this nest owes {}",
            self.macs_done,
            self.expected_macs
        );
        let operand = OperandCounters {
            input_reads: self.macs_done,
            kernel_reads: self.macs_done,
            output_accesses: 2 * self.macs_done,
            input_level: self.operand_levels[0].clone(),
            kernel_level: self.operand_levels[1].clone(),
            output_level: self.operand_levels[2].clone(),
        };
        let mut buffers = Vec::new();
        for (ci, chain) in [&self.input_chain, &self.kernel_chain, &self.output_chain]
            .into_iter()
            .enumerate()
        {
            buffers.extend(self.virtualized[ci].iter().cloned());
            for b in chain {
                buffers.push(BufferCounters {
                    tensor: b.tensor,
                    ordinal: b.ordinal,
                    level: b.level.clone(),
                    size_elems: b.dims4.iter().product(),
                    fill_events: b.fill_events,
                    fill_elems: b.fill_elems,
                    writeback_elems: b.writeback_elems,
                });
            }
        }
        Ok(ConvOutput {
            output: self.dram_out,
            counters: AccessCounters {
                backend: backend.to_string(),
                macs: self.macs_done,
                buffers,
                dram: self.dram,
                operand,
            },
        })
    }
}
