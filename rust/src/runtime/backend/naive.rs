//! The Algorithm 1 reference backend: the unblocked `FwFhXYCK` loop nest
//! with no reuse buffers, wrapping the rust-native
//! [`crate::coordinator::naive_conv`] oracle. Its numeric output defines
//! correctness for every other backend; its access report is what an
//! unblocked implementation pays — every operand fetch is memory
//! traffic, which is exactly the baseline the paper's blocked schedules
//! are measured against.

use super::{AccessCounters, Backend, ConvInputs, ConvOutput, DramCounters, OperandCounters};
use crate::coordinator::naive_conv::conv_valid;
use crate::plan::BlockingPlan;
use anyhow::{ensure, Result};

/// Reference executor: unblocked semantics, no reuse buffers.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveBackend;

impl Backend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    /// Runs the plan's layer with the unblocked nest (the blocking
    /// string is ignored apart from validation — naive semantics do not
    /// block). Counters report the unblocked cost: input and kernel
    /// operands read from DRAM at MAC rate, one output store per output
    /// element (the accumulator lives in a register).
    fn execute(&self, plan: &BlockingPlan, inputs: &ConvInputs) -> Result<ConvOutput> {
        let d = plan.dims;
        ensure!(
            inputs.dims == d,
            "inputs are for {} but the plan is for {}",
            inputs.dims,
            d
        );
        ensure!(
            inputs.input.len() as u64 == d.input_elems()
                && inputs.weights.len() as u64 == d.kernel_elems(),
            "input/weight tensors do not match {}",
            d
        );
        let (h, w) = ((d.y + d.fh - 1) as usize, (d.x + d.fw - 1) as usize);
        let (c, k) = (d.c as usize, d.k as usize);
        let (fh, fw) = (d.fh as usize, d.fw as usize);
        let image = c * h * w;
        let per_out = (d.k * d.y * d.x) as usize;
        let mut output = Vec::with_capacity((d.b as usize) * per_out);
        for b in 0..d.b as usize {
            let img = &inputs.input[b * image..(b + 1) * image];
            output.extend(conv_valid(img, (c, h, w), &inputs.weights, (k, c, fh, fw)));
        }
        let macs = d.macs();
        let counters = AccessCounters {
            backend: "naive".to_string(),
            macs,
            buffers: Vec::new(),
            dram: DramCounters {
                input_loads: macs,
                kernel_loads: macs,
                output_loads: 0,
                output_stores: d.output_elems(),
            },
            operand: OperandCounters {
                input_reads: macs,
                kernel_reads: macs,
                // read+write per MAC in the model's accounting; the
                // register accumulator makes the writes free here, so
                // only the final stores (in `dram`) are real traffic.
                output_accesses: 2 * macs,
                input_level: "DRAM".to_string(),
                kernel_level: "DRAM".to_string(),
                output_level: "DRAM".to_string(),
            },
        };
        Ok(ConvOutput { output, counters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::dims::LayerDims;
    use crate::plan::{Planner, Target};

    fn plan_for(d: LayerDims) -> BlockingPlan {
        Planner::for_named("t", d)
            .target(Target::Cpu)
            .levels(2)
            .plan()
            .unwrap()
    }

    #[test]
    fn matches_conv_valid_per_image() {
        let d = LayerDims::conv(6, 6, 3, 4, 3, 3);
        let plan = plan_for(d);
        let inputs = ConvInputs::synthetic(d, 11);
        let got = NaiveBackend.execute(&plan, &inputs).unwrap();
        let want = conv_valid(&inputs.input, (3, 8, 8), &inputs.weights, (4, 3, 3, 3));
        assert_eq!(got.output, want);
        assert_eq!(got.counters.macs, d.macs());
        assert_eq!(got.counters.dram.input_loads, d.macs());
        assert_eq!(got.counters.dram.output_stores, d.output_elems());
        assert!(got.counters.buffers.is_empty());
    }

    #[test]
    fn batch_images_are_independent() {
        let d = LayerDims::conv(4, 4, 2, 2, 3, 3).with_batch(2);
        let plan = plan_for(d);
        let inputs = ConvInputs::synthetic(d, 5);
        let out = NaiveBackend.execute(&plan, &inputs).unwrap();
        assert_eq!(out.output.len() as u64, d.output_elems());
        // image 1 alone must reproduce the second half of the batch
        let image = (d.c * (d.y + d.fh - 1) * (d.x + d.fw - 1)) as usize;
        let solo = conv_valid(
            &inputs.input[image..],
            (2, 6, 6),
            &inputs.weights,
            (2, 2, 3, 3),
        );
        assert_eq!(&out.output[out.output.len() / 2..], &solo[..]);
    }

    #[test]
    fn rejects_mismatched_inputs() {
        let plan = plan_for(LayerDims::conv(6, 6, 3, 4, 3, 3));
        let other = ConvInputs::synthetic(LayerDims::conv(8, 8, 3, 4, 3, 3), 1);
        assert!(NaiveBackend.execute(&plan, &other).is_err());
    }
}
