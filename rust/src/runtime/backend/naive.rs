//! The Algorithm 1 reference backend: the unblocked `FwFhXYCK` loop nest
//! with no reuse buffers, wrapping the rust-native
//! [`crate::coordinator::naive_conv`] oracle. Its numeric output defines
//! correctness for every other backend; its access report is what an
//! unblocked implementation pays — every operand fetch is memory
//! traffic, which is exactly the baseline the paper's blocked schedules
//! are measured against. Like every backend it reads the `Arc<[f32]>`
//! tensors of [`ConvInputs`] in place — comparing against the oracle
//! never copies the inputs.

use super::{
    AccessCounters, Backend, ConvInputs, ConvOutput, DramCounters, ExecLimits, OperandCounters,
};
use crate::coordinator::naive_conv::conv_valid;
use crate::model::dims::LayerDims;
use crate::plan::BlockingPlan;
use anyhow::{ensure, Result};

/// Reference executor: unblocked semantics, no reuse buffers.
#[derive(Debug, Clone, Copy, Default)]
pub struct NaiveBackend;

/// Memory-rate traffic of the unblocked Algorithm 1 nest, derived from
/// the model's operand semantics (`model::access`): the datapath issues
/// one input read, one kernel read and an output read+write per MAC,
/// and only reuse carried by the *innermost window registers* is free
/// (Table 2 allocates no buffer for innermost `Fw`/`Fh` — "their reuse
/// is served by the operand window registers").
///
/// In the `FwFhXYCK` order the window loops are innermost, so of the
/// three streams only the output accumulator enjoys window-register
/// reuse: each output element folds its `Fw x Fh` window in a register
/// and touches memory once per window position — a read+write per
/// `(x, y, c, k, b)` point, i.e. `2 * MACs / (Fw*Fh)` accesses,
/// `MACs / (Fw*Fh)` of them partial-sum re-reads and as many stores.
/// Input and kernel operands index a fresh element on every window step
/// (the window *slides* over the input; each weight is distinct), so
/// their memory-rate reads stay at one per MAC. With no reuse buffers
/// anywhere, every one of those accesses is DRAM traffic.
fn unblocked_traffic(d: &LayerDims) -> (OperandCounters, DramCounters) {
    let macs = d.macs();
    let window = d.fw * d.fh;
    let out_points = macs / window; // (x, y, c, k, b) combinations
    let operand = OperandCounters {
        input_reads: macs,
        kernel_reads: macs,
        output_accesses: 2 * out_points,
        input_level: "DRAM".to_string(),
        kernel_level: "DRAM".to_string(),
        output_level: "DRAM".to_string(),
    };
    let dram = DramCounters {
        input_loads: macs,
        kernel_loads: macs,
        output_loads: out_points,
        output_stores: out_points,
    };
    (operand, dram)
}

impl Backend for NaiveBackend {
    fn name(&self) -> &'static str {
        "naive"
    }

    /// Runs the plan's layer with the unblocked nest (the blocking
    /// string is ignored apart from validation — naive semantics do not
    /// block). Counters report the unblocked memory-rate cost derived
    /// in [`unblocked_traffic`].
    fn execute_with(
        &self,
        plan: &BlockingPlan,
        inputs: &ConvInputs,
        limits: ExecLimits,
    ) -> Result<ConvOutput> {
        let d = plan.dims;
        ensure!(
            inputs.dims == d,
            "inputs are for {} but the plan is for {}",
            inputs.dims,
            d
        );
        ensure!(
            inputs.input.len() as u64 == d.input_elems()
                && inputs.weights.len() as u64 == d.kernel_elems(),
            "input/weight tensors do not match {}",
            d
        );
        // The unblocked nest allocates nothing beyond the output
        // tensor; price that plus the MAC count against the ceilings.
        limits
            .check(d.macs(), d.output_elems().saturating_mul(4))
            .map_err(anyhow::Error::new)?;
        let (h, w) = ((d.y + d.fh - 1) as usize, (d.x + d.fw - 1) as usize);
        let (c, k) = (d.c as usize, d.k as usize);
        let (fh, fw) = (d.fh as usize, d.fw as usize);
        let image = c * h * w;
        let per_out = (d.k * d.y * d.x) as usize;
        let mut output = Vec::with_capacity((d.b as usize) * per_out);
        for b in 0..d.b as usize {
            let img = &inputs.input[b * image..(b + 1) * image];
            output.extend(conv_valid(img, (c, h, w), &inputs.weights, (k, c, fh, fw)));
        }
        let (operand, dram) = unblocked_traffic(&d);
        let counters = AccessCounters {
            backend: "naive".to_string(),
            macs: d.macs(),
            buffers: Vec::new(),
            dram,
            operand,
        };
        Ok(ConvOutput { output, counters })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::dims::LayerDims;
    use crate::plan::{Planner, Target};

    fn plan_for(d: LayerDims) -> BlockingPlan {
        Planner::for_named("t", d)
            .target(Target::Cpu)
            .levels(2)
            .plan()
            .unwrap()
    }

    #[test]
    fn matches_conv_valid_per_image() {
        let d = LayerDims::conv(6, 6, 3, 4, 3, 3);
        let plan = plan_for(d);
        let inputs = ConvInputs::synthetic(d, 11);
        let got = NaiveBackend.execute(&plan, &inputs).unwrap();
        let want = conv_valid(&inputs.input, (3, 8, 8), &inputs.weights, (4, 3, 3, 3));
        assert_eq!(got.output, want);
        assert_eq!(got.counters.macs, d.macs());
        assert_eq!(got.counters.dram.input_loads, d.macs());
        // one store per (x, y, c, k) point: the window accumulator is
        // the only register reuse the unblocked nest has
        assert_eq!(got.counters.dram.output_stores, d.macs() / (d.fw * d.fh));
        assert!(got.counters.buffers.is_empty());
    }

    #[test]
    fn memory_rate_counters_follow_model_semantics() {
        // The satellite pin: naive counters must be derived from the
        // model's operand semantics (`model::access`), not flat MAC
        // multiples. Input/kernel streams have no window-register reuse
        // (fresh element per window step) and stay at MAC rate; the
        // output accumulator folds the Fw x Fh window in a register, so
        // its memory-rate accesses are the model's 2/MAC divided by the
        // window size.
        let d = LayerDims::conv(8, 8, 4, 4, 3, 3);
        let plan = plan_for(d);
        let out = NaiveBackend
            .execute(&plan, &ConvInputs::synthetic(d, 9))
            .unwrap();
        let prof = crate::model::access::analyze(
            &crate::model::string::BlockingString::unblocked(&d),
            &d,
        )
        .1;
        let window = d.fw * d.fh;
        let op = &out.counters.operand;
        assert_eq!(op.input_reads as f64, prof.operand.input_reads);
        assert_eq!(op.kernel_reads as f64, prof.operand.kernel_reads);
        assert_eq!(
            op.output_accesses as f64,
            prof.operand.output_accesses / window as f64
        );
        // removing the window-register reuse recovers the MAC rate
        assert_eq!(op.output_accesses * window, 2 * d.macs());
        // every access is DRAM traffic: no reuse buffers anywhere
        assert_eq!(op.input_level, "DRAM");
        assert_eq!(out.counters.dram.input_loads, op.input_reads);
        assert_eq!(out.counters.dram.kernel_loads, op.kernel_reads);
        assert_eq!(
            out.counters.dram.output_loads + out.counters.dram.output_stores,
            op.output_accesses
        );
    }

    #[test]
    fn batch_images_are_independent() {
        let d = LayerDims::conv(4, 4, 2, 2, 3, 3).with_batch(2);
        let plan = plan_for(d);
        let inputs = ConvInputs::synthetic(d, 5);
        let out = NaiveBackend.execute(&plan, &inputs).unwrap();
        assert_eq!(out.output.len() as u64, d.output_elems());
        // image 1 alone must reproduce the second half of the batch
        let image = (d.c * (d.y + d.fh - 1) * (d.x + d.fw - 1)) as usize;
        let solo = conv_valid(
            &inputs.input[image..],
            (2, 6, 6),
            &inputs.weights,
            (2, 2, 3, 3),
        );
        assert_eq!(&out.output[out.output.len() / 2..], &solo[..]);
    }

    #[test]
    fn rejects_mismatched_inputs() {
        let plan = plan_for(LayerDims::conv(6, 6, 3, 4, 3, 3));
        let other = ConvInputs::synthetic(LayerDims::conv(8, 8, 3, 4, 3, 3), 1);
        assert!(NaiveBackend.execute(&plan, &other).is_err());
    }
}
