//! Execution runtimes: the plan-level [`backend`] executors (naive
//! reference, blocked per-MAC interpreter, the tiled SIMD fast path
//! and its parallel-sharded variant, all with measured access
//! counters) and the PJRT engine that
//! loads AOT HLO-text artifacts onto
//! the CPU PJRT client — the only place the `xla` crate is touched.
//! Python never runs here; the artifacts are self-contained (weights
//! baked in as HLO constants by `python/compile/aot.py`).
//!
//! Interchange is HLO *text* (not serialized protos): jax >= 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).

pub mod backend;
#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;

pub use backend::{
    AccessCounters, Backend, BlockedCpuBackend, ConvInputs, ConvOutput, NaiveBackend,
    ParallelTiledBackend, TiledCpuBackend,
};
pub use engine::{Engine, Module};
pub use manifest::{ArtifactSpec, Golden, Manifest};
