//! Artifact manifest (written by aot.py): shapes per artifact plus the
//! schedules compiled into each kernel, so the coordinator can report the
//! blocking it is actually running. The schedule records are rehydrated
//! into full [`BlockingPlan`]s (re-evaluated on the export target), so the
//! serving path speaks the same plan IR as the optimizer that produced
//! the artifacts.

use crate::plan::BlockingPlan;
use crate::util::json::{parse, Json};
use anyhow::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape contract of one compiled artifact.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (file stem of the HLO text).
    pub name: String,
    /// Input shapes (row-major dims), all f32.
    pub inputs: Vec<Vec<usize>>,
    /// Output shape.
    pub output: Vec<usize>,
}

impl ArtifactSpec {
    /// Flat element count of input `i`.
    pub fn input_len(&self, i: usize) -> usize {
        self.inputs[i].iter().product()
    }

    /// Flat element count of the output.
    pub fn output_len(&self) -> usize {
        self.output.iter().product()
    }
}

/// The artifact manifest `aot.py` writes next to the HLO files.
#[derive(Debug, Clone)]
pub struct Manifest {
    /// Directory the manifest (and artifacts) live in.
    pub dir: PathBuf,
    /// Shape contract per artifact name.
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Blocking-string notation per pipeline layer (from schedules.json).
    pub layer_strings: Vec<String>,
    /// The plan that produced each pipeline executable, rehydrated from
    /// the manifest's schedule records. Empty if the manifest predates
    /// schedule embedding or *any* record fails to parse — a partial
    /// list would misattribute plans to layers by position.
    pub layer_plans: Vec<BlockingPlan>,
}

/// Rebuild one plan from a manifest schedule record (aot.py embeds the
/// schedules.json rows verbatim, so this is the schedules-row parser
/// plus a re-evaluation on the export target).
fn plan_from_schedule_entry(l: &Json) -> Option<BlockingPlan> {
    crate::optimizer::schedules::layer_from_json(l)
        .and_then(|s| s.to_plan("manifest"))
        .ok()
}

fn shape_of(j: &Json) -> Result<Vec<usize>> {
    // ["f32", [d0, d1, ...]]
    let dims = j
        .idx(1)
        .and_then(|d| d.as_arr())
        .ok_or_else(|| anyhow!("bad shape spec"))?;
    dims.iter()
        .map(|v| {
            v.as_u64()
                .map(|u| u as usize)
                .ok_or_else(|| anyhow!("bad dim"))
        })
        .collect()
}

impl Manifest {
    /// Read and parse `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json (run `make artifacts`)", dir.display()))?;
        let j = parse(&text).context("parsing manifest.json")?;
        let arts = j
            .get("artifacts")
            .ok_or_else(|| anyhow!("manifest missing artifacts"))?;
        let mut artifacts = BTreeMap::new();
        if let Json::Obj(m) = arts {
            for (name, spec) in m {
                let inputs = spec
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("{}: missing inputs", name))?
                    .iter()
                    .map(shape_of)
                    .collect::<Result<Vec<_>>>()?;
                let output = shape_of(
                    spec.get("output")
                        .ok_or_else(|| anyhow!("{}: missing output", name))?,
                )?;
                artifacts.insert(
                    name.clone(),
                    ArtifactSpec {
                        name: name.clone(),
                        inputs,
                        output,
                    },
                );
            }
        }
        let layer_strings = j
            .get("schedules")
            .and_then(|s| s.as_arr())
            .map(|layers| {
                layers
                    .iter()
                    .map(|l| {
                        l.get("string")
                            .and_then(|v| v.as_str())
                            .unwrap_or("")
                            .to_string()
                    })
                    .collect()
            })
            .unwrap_or_default();
        // All-or-nothing: a partially parsed list would misalign plans
        // with pipeline layers, so any bad record empties the whole list
        // (callers fall back to layer_strings).
        let layer_plans = j
            .get("schedules")
            .and_then(|s| s.as_arr())
            .and_then(|layers| {
                layers
                    .iter()
                    .map(plan_from_schedule_entry)
                    .collect::<Option<Vec<_>>>()
            })
            .unwrap_or_default();
        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            layer_strings,
            layer_plans,
        })
    }

    /// Shape contract of a named artifact.
    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{}' not in manifest", name))
    }

    /// Path of a named artifact's HLO text file.
    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{}.hlo.txt", name))
    }

    /// The compiled pipeline batch sizes, ascending.
    pub fn batch_ladder(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .artifacts
            .keys()
            .filter_map(|k| k.strip_prefix("alexnet_mini_b"))
            .filter_map(|b| b.parse().ok())
            .collect();
        v.sort();
        v
    }
}

/// Load the golden input/output pair exported by aot.py.
#[derive(Debug, Clone)]
pub struct Golden {
    /// Shape of the golden input tensor.
    pub input_shape: Vec<usize>,
    /// Golden input, row-major.
    pub input: Vec<f32>,
    /// Shape of the golden output tensor.
    pub output_shape: Vec<usize>,
    /// Golden output, row-major.
    pub output: Vec<f32>,
}

impl Golden {
    /// Read and parse `<dir>/golden.json`.
    pub fn load(dir: &Path) -> Result<Golden> {
        let text = std::fs::read_to_string(dir.join("golden.json"))
            .context("reading golden.json (run `make artifacts`)")?;
        let j = parse(&text).context("parsing golden.json")?;
        let floats = |key: &str| -> Result<Vec<f32>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("golden missing {}", key))?
                .iter()
                .map(|v| v.as_f64().map(|f| f as f32).ok_or_else(|| anyhow!("bad f")))
                .collect()
        };
        let shape = |key: &str| -> Result<Vec<usize>> {
            j.get(key)
                .and_then(|v| v.as_arr())
                .ok_or_else(|| anyhow!("golden missing {}", key))?
                .iter()
                .map(|v| v.as_u64().map(|u| u as usize).ok_or_else(|| anyhow!("bad dim")))
                .collect()
        };
        Ok(Golden {
            input_shape: shape("input_shape")?,
            input: floats("input")?,
            output_shape: shape("output_shape")?,
            output: floats("output")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_manifest_when_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.spec("quickstart").is_ok());
        let qs = m.spec("quickstart").unwrap();
        assert_eq!(qs.inputs.len(), 2);
        assert_eq!(qs.inputs[0], vec![4, 10, 10]);
        assert_eq!(qs.output, vec![8, 8, 8]);
        assert_eq!(m.batch_ladder(), vec![1, 2, 4, 8]);
        assert_eq!(m.layer_strings.len(), 3);
        // the schedule records rehydrate into full plans
        assert_eq!(m.layer_plans.len(), 3);
        for p in &m.layer_plans {
            p.string.validate(&p.dims).unwrap();
            assert_eq!(p.provenance.origin, "manifest");
        }
    }

    #[test]
    fn golden_pair_consistent() {
        let dir = artifacts_dir();
        if !dir.join("golden.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let g = Golden::load(&dir).unwrap();
        assert_eq!(
            g.input.len(),
            g.input_shape.iter().product::<usize>()
        );
        assert_eq!(
            g.output.len(),
            g.output_shape.iter().product::<usize>()
        );
    }

    #[test]
    fn missing_dir_is_a_clean_error() {
        let err = Manifest::load(Path::new("/nonexistent")).unwrap_err();
        assert!(err.to_string().contains("manifest.json"));
    }
}
