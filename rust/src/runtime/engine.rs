//! PJRT engine: compile HLO text once, execute many times.

use super::manifest::ArtifactSpec;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Owns the PJRT client. One per process (CPU client spawns its own
/// thread pool). Not Send: create it on the thread that executes.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {:?}", e))?;
        Ok(Engine { client })
    }

    /// Platform name the client reports (e.g. `cpu`).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load(&self, path: &Path, spec: &ArtifactSpec) -> Result<Module> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| anyhow!("parsing {}: {:?}", path.display(), e))
            .with_context(|| "HLO text load (run `make artifacts`?)")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {:?}", path.display(), e))?;
        Ok(Module {
            exe,
            spec: spec.clone(),
        })
    }
}

/// A compiled executable + its shape contract.
pub struct Module {
    exe: xla::PjRtLoadedExecutable,
    /// Shape contract from the artifact manifest.
    pub spec: ArtifactSpec,
}

impl Module {
    /// Execute with f32 inputs (row-major, shapes per the manifest spec).
    /// Returns the flattened f32 output.
    pub fn run_f32(&self, inputs: &[&[f32]]) -> Result<Vec<f32>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "{}: expected {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, data) in inputs.iter().enumerate() {
            let want = self.spec.input_len(i);
            if data.len() != want {
                return Err(anyhow!(
                    "{}: input {} has {} elements, expected {}",
                    self.spec.name,
                    i,
                    data.len(),
                    want
                ));
            }
            let dims: Vec<i64> = self.spec.inputs[i].iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {}: {:?}", i, e))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {:?}", self.spec.name, e))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {:?}", e))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("to_tuple1: {:?}", e))?;
        let values = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec<f32>: {:?}", e))?;
        if values.len() != self.spec.output_len() {
            return Err(anyhow!(
                "{}: output has {} elements, expected {}",
                self.spec.name,
                values.len(),
                self.spec.output_len()
            ));
        }
        Ok(values)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Golden, Manifest};
    use std::path::PathBuf;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn ready() -> bool {
        artifacts_dir().join("manifest.json").exists()
    }

    #[test]
    fn quickstart_matches_native_conv() {
        if !ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let engine = Engine::cpu().unwrap();
        let module = engine
            .load(&m.hlo_path("quickstart"), m.spec("quickstart").unwrap())
            .unwrap();
        // deterministic pseudo-random inputs
        let mut rng = crate::util::rng::Rng::new(77);
        let x: Vec<f32> = (0..4 * 10 * 10).map(|_| rng.f64() as f32 - 0.5).collect();
        let w: Vec<f32> = (0..8 * 4 * 3 * 3).map(|_| rng.f64() as f32 - 0.5).collect();
        let got = module.run_f32(&[&x, &w]).unwrap();
        let want = crate::coordinator::naive_conv::conv_valid(&x, (4, 10, 10), &w, (8, 4, 3, 3));
        assert_eq!(got.len(), want.len());
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 1e-4, "PJRT {} vs native {}", g, wv);
        }
    }

    #[test]
    fn pipeline_reproduces_golden() {
        if !ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let g = Golden::load(&artifacts_dir()).unwrap();
        let engine = Engine::cpu().unwrap();
        let module = engine
            .load(
                &m.hlo_path("alexnet_mini_b1"),
                m.spec("alexnet_mini_b1").unwrap(),
            )
            .unwrap();
        let got = module.run_f32(&[&g.input]).unwrap();
        assert_eq!(got.len(), g.output.len());
        let max_err = got
            .iter()
            .zip(&g.output)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_err < 1e-3, "golden mismatch: max err {}", max_err);
    }

    #[test]
    fn shape_errors_are_caught() {
        if !ready() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&artifacts_dir()).unwrap();
        let engine = Engine::cpu().unwrap();
        let module = engine
            .load(&m.hlo_path("quickstart"), m.spec("quickstart").unwrap())
            .unwrap();
        let too_short = vec![0f32; 7];
        let w = vec![0f32; 8 * 4 * 3 * 3];
        assert!(module.run_f32(&[&too_short, &w]).is_err());
        assert!(module.run_f32(&[&w]).is_err());
    }
}
