"""Layer 2: the JAX model — conv layers built on the L1 Pallas kernel.

Everything here runs only at build time (``make artifacts``); the rust
coordinator executes the AOT-lowered HLO through PJRT and python is never
on the request path.

The pipeline ("AlexNet-mini", DESIGN.md §6) chains three conv layers with
ReLU and 2x2 max-pools; spatial dims chain exactly (36² -> 32² -pool->
16² -> 14² -pool-> 7² -> 5²), so no padding is needed.
"""

import json
import os

import jax
import jax.numpy as jnp

from .kernels.blocked_conv import blocked_conv

DEFAULT_SCHEDULES = os.path.join(os.path.dirname(__file__), "schedules.json")


def load_schedules(path=DEFAULT_SCHEDULES):
    """Read the rust optimizer's schedule export. Returns a list of layer
    dicts with 'name', 'dims' {x,y,c,k,fw,fh} and 'tile' [x0,y0,c0,k0]."""
    with open(path) as f:
        data = json.load(f)
    assert data.get("version") == 1, "unknown schedules.json version"
    return data["layers"]


def conv_layer(x, w, b, *, tile, fh, fw):
    """One conv layer: blocked conv + bias + ReLU.

    x: (C, H, W); w: (K, C, Fh, Fw); b: (K,). tile = (x0, y0, c0, k0)
    from the optimizer — only (c0, k0) shape the Pallas grid (see
    blocked_conv.py).
    """
    _, _, c0, k0 = tile
    out = blocked_conv(x, w, c0=int(c0), k0=int(k0), fh=fh, fw=fw)
    return jax.nn.relu(out + b[:, None, None])


def maxpool2(x):
    k, y, xd = x.shape
    y2, x2 = y - (y % 2), xd - (xd % 2)
    x = x[:, :y2, :x2]
    return jnp.max(x.reshape(k, y2 // 2, 2, x2 // 2, 2), axis=(2, 4))


def init_params(schedules, seed=0):
    """Deterministic synthetic weights for the pipeline (the blocking
    behaviour depends only on dims; numerics are verified against the
    oracle and against the rust-native conv)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for layer in schedules:
        d = layer["dims"]
        key, kw, kb = jax.random.split(key, 3)
        w = jax.random.normal(
            kw, (d["k"], d["c"], d["fh"], d["fw"]), dtype=jnp.float32
        ) * (2.0 / (d["c"] * d["fh"] * d["fw"])) ** 0.5
        b = jax.random.normal(kb, (d["k"],), dtype=jnp.float32) * 0.01
        params.append((w, b))
    return params


def pipeline(x, params, schedules):
    """AlexNet-mini forward for one image: conv->relu->pool, x3 convs.

    x: (C1, 36, 36) -> returns (K3, 5, 5).
    """
    assert len(params) == len(schedules) == 3
    h = conv_layer(
        x, *params[0], tile=schedules[0]["tile"],
        fh=schedules[0]["dims"]["fh"], fw=schedules[0]["dims"]["fw"],
    )
    h = maxpool2(h)
    h = conv_layer(
        h, *params[1], tile=schedules[1]["tile"],
        fh=schedules[1]["dims"]["fh"], fw=schedules[1]["dims"]["fw"],
    )
    h = maxpool2(h)
    h = conv_layer(
        h, *params[2], tile=schedules[2]["tile"],
        fh=schedules[2]["dims"]["fh"], fw=schedules[2]["dims"]["fw"],
    )
    return h


def batched_pipeline(params, schedules):
    """vmap the pipeline over a leading batch dim: (B, C, H, W)."""
    def fn(xb):
        return jax.vmap(lambda x: pipeline(x, params, schedules))(xb)
    return fn


def single_layer_fn(layer, params):
    """A single conv layer as a standalone jittable fn (per-layer
    artifacts used by the runtime round-trip tests)."""
    w, b = params
    d = layer["dims"]

    def fn(x):
        return conv_layer(x, w, b, tile=layer["tile"], fh=d["fh"], fw=d["fw"])

    return fn


def input_shape(schedules):
    d = schedules[0]["dims"]
    return (d["c"], d["y"] + d["fh"] - 1, d["x"] + d["fw"] - 1)
