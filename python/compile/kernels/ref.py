"""Pure-jnp correctness oracle for the blocked convolution kernel.

Implemented two independent ways — lax.conv_general_dilated and an
explicit window sum — so a bug in either path cannot silently agree with
the Pallas kernel.
"""

import jax
import jax.numpy as jnp


def conv_ref(x, w):
    """Valid convolution (cross-correlation, CNN convention) of a (C,H,W)
    input with (K,C,Fh,Fw) weights -> (K,Y,X), via lax.conv."""
    out = jax.lax.conv_general_dilated(
        x[None, ...].astype(jnp.float32),
        w.astype(jnp.float32),
        window_strides=(1, 1),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return out[0].astype(x.dtype)


def conv_naive(x, w):
    """Explicit shifted-window sum (slow; only for tiny shapes)."""
    k, c, fh, fw = w.shape
    _, h, wd = x.shape
    y_out, x_out = h - fh + 1, wd - fw + 1
    acc = jnp.zeros((k, y_out, x_out), dtype=jnp.float32)
    for dy in range(fh):
        for dx in range(fw):
            window = x[:, dy : dy + y_out, dx : dx + x_out].astype(jnp.float32)
            acc = acc + jnp.tensordot(
                w[:, :, dy, dx].astype(jnp.float32), window, axes=((1,), (0,))
            )
    return acc.astype(x.dtype)


def maxpool2_ref(x):
    """2x2/stride-2 max pool over (K, Y, X); truncates odd remainders."""
    k, y, xd = x.shape
    y2, x2 = y - (y % 2), xd - (xd % 2)
    x = x[:, :y2, :x2]
    return jnp.max(x.reshape(k, y2 // 2, 2, x2 // 2, 2), axis=(2, 4))
