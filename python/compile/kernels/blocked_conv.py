"""Layer 1: blocked direct-convolution Pallas kernel.

The kernel's blocking comes from the rust optimizer (L3): ``cnnblk
optimize --emit-schedules`` writes ``schedules.json`` whose level-0 tile
``(x0, y0, c0, k0)`` parameterizes the ``pallas_call`` grid and BlockSpecs
here. The channel tiles (c0, k0) become grid dimensions (the HBM<->VMEM
schedule the paper expressed with its C/K loop splits); the spatial tile
(x0, y0) governs the within-block compute order and the VMEM-footprint
estimate recorded in DESIGN.md §Hardware-Adaptation (overlapping halo
blocks cannot be expressed as disjoint Pallas BlockSpecs, so spatial
blocking stays inside the block — exactly the role the paper gives the
innermost shift-register level).

``interpret=True`` is mandatory on this image: real TPU lowering emits a
Mosaic custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Tensor layouts (single image):
#   input   (C, H, W)  with  H = Y + Fh - 1, W = X + Fw - 1   ("valid")
#   weights (K, C, Fh, Fw)
#   output  (K, Y, X)


def _conv_block_kernel(x_ref, w_ref, o_ref, *, fh: int, fw: int):
    """Compute one (c-tile, k-tile) block: o += conv(x_block, w_block).

    x_ref: (c0, H, W) input channels tile (full spatial extent + halo)
    w_ref: (k0, c0, fh, fw)
    o_ref: (k0, Y, X) accumulated across the c grid dimension.
    """
    ci = pl.program_id(1)  # reduction position (c tiles iterate fastest)

    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    k0, y, xdim = o_ref.shape

    acc = jnp.zeros((k0, y, xdim), dtype=jnp.float32)
    # The Fw/Fh window loops are innermost (Algorithm 1); each (dy, dx)
    # offset contributes a shifted input slab contracted over the c tile.
    for dy in range(fh):
        for dx in range(fw):
            # (c0, Y, X) window starting at (dy, dx)
            window = jax.lax.dynamic_slice(
                x, (0, dy, dx), (x.shape[0], y, xdim)
            )
            # (k0, c0) x (c0, Y*X) -> (k0, Y, X)
            wslice = w[:, :, dy, dx]
            acc = acc + jnp.tensordot(wslice, window, axes=((1,), (0,)))

    @pl.when(ci == 0)
    def _init():
        o_ref[...] = acc.astype(o_ref.dtype)

    @pl.when(ci != 0)
    def _accum():
        o_ref[...] = (o_ref[...].astype(jnp.float32) + acc).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("c0", "k0", "fh", "fw", "interpret")
)
def blocked_conv(x, w, *, c0: int, k0: int, fh: int, fw: int, interpret: bool = True):
    """Valid 2-D convolution of (C,H,W) by (K,C,Fh,Fw) -> (K,Y,X), blocked
    per the optimizer's (c0, k0) tile."""
    c, h, wdim = x.shape
    k = w.shape[0]
    assert w.shape == (k, c, fh, fw), (w.shape, (k, c, fh, fw))
    assert c % c0 == 0 and k % k0 == 0, (c, c0, k, k0)
    y_out, x_out = h - fh + 1, wdim - fw + 1

    grid = (k // k0, c // c0)  # c tiles innermost (accumulation)
    return pl.pallas_call(
        functools.partial(_conv_block_kernel, fh=fh, fw=fw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((c0, h, wdim), lambda ki, ci: (ci, 0, 0)),
            pl.BlockSpec((k0, c0, fh, fw), lambda ki, ci: (ki, ci, 0, 0)),
        ],
        out_specs=pl.BlockSpec((k0, y_out, x_out), lambda ki, ci: (ki, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((k, y_out, x_out), x.dtype),
        interpret=interpret,
    )(x, w)


def vmem_estimate_bytes(c0: int, k0: int, x0: int, y0: int, fh: int, fw: int,
                        h: int, w: int, y: int, x: int, elem_bytes: int = 4):
    """VMEM footprint of one grid step (DESIGN.md §Perf, L1 profile):
    input tile + weight tile + output tile, using the optimizer's spatial
    tile for the shift-register level estimate."""
    del x0, y0  # spatial tile informs the register level, not VMEM blocks
    input_tile = c0 * h * w
    weight_tile = k0 * c0 * fh * fw
    output_tile = k0 * y * x
    return (input_tile + weight_tile + output_tile) * elem_bytes
