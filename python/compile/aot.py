"""AOT lowering: JAX/Pallas -> HLO *text* artifacts for the rust runtime.

HLO text, NOT ``lowered.compiler_ir(...).serialize()``: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts produced (all f32):
  artifacts/quickstart.hlo.txt          tiny conv, runtime smoke test
  artifacts/<layer>.hlo.txt             each pipeline layer standalone
  artifacts/alexnet_mini_b{1,2,4,8}.hlo.txt
                                        full 3-layer pipeline at the
                                        coordinator's batch ladder
  artifacts/manifest.json               shapes + params checksums
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import (
    batched_pipeline,
    init_params,
    input_shape,
    load_schedules,
    single_layer_fn,
)
from .kernels.blocked_conv import blocked_conv

BATCH_LADDER = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants is essential: the default printer elides big
    # literals as `constant({...})`, which the rust-side text parser
    # accepts but fills with garbage — baked weights would be destroyed.
    opts = xc._xla.HloPrintOptions()
    opts.print_large_constants = True
    # new-jax metadata attributes (source_end_line etc.) are rejected by
    # the 0.5.1 text parser on the rust side — strip metadata entirely.
    opts.print_metadata = False
    text = comp.get_hlo_module().to_string(opts)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def lower_fn(fn, *example_args):
    return to_hlo_text(jax.jit(fn).lower(*example_args))


def write(path, text):
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write(text)
    print(f"wrote {len(text):>9} chars  {path}")


def checksum(arr) -> str:
    return hashlib.sha256(np.asarray(arr).tobytes()).hexdigest()[:16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--schedules", default=None)
    args = ap.parse_args()

    schedules = load_schedules(args.schedules) if args.schedules else load_schedules()
    params = init_params(schedules)
    out = args.out_dir

    manifest = {"version": 1, "artifacts": {}}

    # --- quickstart: one tiny blocked conv, fixed weights ------------
    qx = jax.ShapeDtypeStruct((4, 10, 10), jnp.float32)
    qw = jax.ShapeDtypeStruct((8, 4, 3, 3), jnp.float32)

    def quickstart(x, w):
        return (blocked_conv(x, w, c0=4, k0=4, fh=3, fw=3),)

    write(os.path.join(out, "quickstart.hlo.txt"), lower_fn(quickstart, qx, qw))
    manifest["artifacts"]["quickstart"] = {
        "inputs": [["f32", list(qx.shape)], ["f32", list(qw.shape)]],
        "output": ["f32", [8, 8, 8]],
    }

    # --- per-layer artifacts (weights baked in as constants) ---------
    for layer, p in zip(schedules, params):
        d = layer["dims"]
        shape = (d["c"], d["y"] + d["fh"] - 1, d["x"] + d["fw"] - 1)
        spec = jax.ShapeDtypeStruct(shape, jnp.float32)
        fn = single_layer_fn(layer, p)
        write(
            os.path.join(out, f"{layer['name']}.hlo.txt"),
            lower_fn(lambda x: (fn(x),), spec),
        )
        manifest["artifacts"][layer["name"]] = {
            "inputs": [["f32", list(shape)]],
            "output": ["f32", [d["k"], d["y"], d["x"]]],
            "tile": layer["tile"],
            "string": layer["string"],
            "weights_sha": checksum(p[0]),
        }

    # --- full pipeline at each batch size -----------------------------
    in_shape = input_shape(schedules)
    pipe = batched_pipeline(params, schedules)
    last = schedules[-1]["dims"]
    for b in BATCH_LADDER:
        spec = jax.ShapeDtypeStruct((b,) + in_shape, jnp.float32)
        write(
            os.path.join(out, f"alexnet_mini_b{b}.hlo.txt"),
            lower_fn(lambda xb: (pipe(xb),), spec),
        )
        manifest["artifacts"][f"alexnet_mini_b{b}"] = {
            "inputs": [["f32", [b] + list(in_shape)]],
            "output": ["f32", [b, last["k"], last["y"], last["x"]]],
        }

    manifest["schedules"] = schedules
    manifest["params_sha"] = [checksum(w) for (w, _b) in params]
    write(os.path.join(out, "manifest.json"), json.dumps(manifest, indent=2, sort_keys=True))

    # --- golden pair: deterministic input -> pipeline output ----------
    # The rust e2e driver replays this input through the compiled b1
    # artifact and asserts bitwise-close agreement: a cross-language check
    # of the entire AOT path (weights are baked into the HLO).
    gx = jax.random.normal(jax.random.PRNGKey(1234), in_shape, dtype=jnp.float32)
    gout = pipe(gx[None, ...])[0]
    # per-stage intermediates: input to each standalone layer artifact and
    # its expected output, so the rust tests can pinpoint a diverging stage
    from .model import maxpool2

    stages = []
    h = gx
    for layer, p in zip(schedules, params):
        fn = single_layer_fn(layer, p)
        o = fn(h)
        stages.append(
            {
                "name": layer["name"],
                "input_shape": list(h.shape),
                "input": np.asarray(h).ravel().tolist(),
                "output_shape": list(o.shape),
                "output": np.asarray(o).ravel().tolist(),
            }
        )
        h = maxpool2(o) if layer is not schedules[-1] else o
    golden = {
        "input_shape": list(in_shape),
        "input": np.asarray(gx).ravel().tolist(),
        "output_shape": list(gout.shape),
        "output": np.asarray(gout).ravel().tolist(),
        "stages": stages,
    }
    write(os.path.join(out, "golden.json"), json.dumps(golden))


if __name__ == "__main__":
    main()
