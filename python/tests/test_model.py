"""L2 correctness: pipeline shapes, schedule loading, pooling, batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import conv_ref, maxpool2_ref
from compile.model import (
    batched_pipeline,
    conv_layer,
    init_params,
    input_shape,
    load_schedules,
    maxpool2,
    pipeline,
)


@pytest.fixture(scope="module")
def schedules():
    return load_schedules()


@pytest.fixture(scope="module")
def params(schedules):
    return init_params(schedules)


def test_schedules_well_formed(schedules):
    assert len(schedules) == 3
    for layer in schedules:
        d = layer["dims"]
        x0, y0, c0, k0 = layer["tile"]
        assert d["x"] % x0 == 0 and d["y"] % y0 == 0
        assert d["c"] % c0 == 0 and d["k"] % k0 == 0


def test_layers_chain_spatially(schedules):
    """mini1 out --pool--> mini2 in --pool--> mini3 in, exactly."""
    d1, d2, d3 = (layer["dims"] for layer in schedules)
    assert d1["x"] // 2 == d2["x"] + d2["fw"] - 1
    assert d2["x"] // 2 == d3["x"] + d3["fw"] - 1
    assert d1["k"] == d2["c"] and d2["k"] == d3["c"]


def test_pipeline_shape(schedules, params):
    x = jnp.ones(input_shape(schedules), dtype=jnp.float32)
    out = pipeline(x, params, schedules)
    d3 = schedules[-1]["dims"]
    assert out.shape == (d3["k"], d3["y"], d3["x"])
    assert bool(jnp.all(out >= 0))  # ReLU output


def test_conv_layer_matches_oracle(schedules, params):
    layer = schedules[0]
    d = layer["dims"]
    x = jax.random.normal(
        jax.random.PRNGKey(9),
        (d["c"], d["y"] + d["fh"] - 1, d["x"] + d["fw"] - 1),
    )
    w, b = params[0]
    got = conv_layer(x, w, b, tile=layer["tile"], fh=d["fh"], fw=d["fw"])
    want = jax.nn.relu(conv_ref(x, w) + b[:, None, None])
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


def test_maxpool_matches_ref():
    x = jax.random.normal(jax.random.PRNGKey(2), (3, 9, 8))
    np.testing.assert_allclose(maxpool2(x), maxpool2_ref(x))


def test_batched_pipeline_equals_stacked_singles(schedules, params):
    xb = jax.random.normal(jax.random.PRNGKey(4), (3,) + input_shape(schedules))
    batched = batched_pipeline(params, schedules)(xb)
    singles = jnp.stack([pipeline(xb[i], params, schedules) for i in range(3)])
    np.testing.assert_allclose(batched, singles, rtol=1e-5, atol=1e-6)


def test_params_deterministic(schedules):
    a = init_params(schedules, seed=0)
    b = init_params(schedules, seed=0)
    for (wa, ba), (wb, bb) in zip(a, b):
        np.testing.assert_array_equal(wa, wb)
        np.testing.assert_array_equal(ba, bb)
    c = init_params(schedules, seed=1)
    assert not np.array_equal(np.asarray(a[0][0]), np.asarray(c[0][0]))
