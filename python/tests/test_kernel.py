"""L1 correctness: the Pallas blocked-conv kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute hot-spot: exact
parametrized cases, a hypothesis sweep over shapes/tiles/dtypes, and
cross-checks between the two independent reference implementations.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.blocked_conv import blocked_conv, vmem_estimate_bytes
from compile.kernels.ref import conv_naive, conv_ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=dtype)


@pytest.mark.parametrize(
    "c,k,y,x,fh,fw,c0,k0",
    [
        (4, 8, 8, 8, 3, 3, 4, 4),
        (8, 16, 32, 32, 5, 5, 8, 8),
        (16, 32, 14, 14, 3, 3, 8, 8),
        (32, 32, 5, 5, 3, 3, 8, 8),
        (1, 1, 4, 4, 1, 1, 1, 1),
        (2, 4, 6, 6, 2, 2, 1, 2),
        (8, 8, 8, 8, 11, 11, 2, 8),
    ],
)
def test_kernel_matches_ref(c, k, y, x, fh, fw, c0, k0):
    xin = rand(1, (c, y + fh - 1, x + fw - 1))
    w = rand(2, (k, c, fh, fw))
    got = blocked_conv(xin, w, c0=c0, k0=k0, fh=fh, fw=fw)
    want = conv_ref(xin, w)
    assert got.shape == (k, y, x)
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_refs_agree_with_each_other():
    xin = rand(3, (4, 10, 10))
    w = rand(4, (8, 4, 3, 3))
    np.testing.assert_allclose(conv_ref(xin, w), conv_naive(xin, w), rtol=1e-5, atol=1e-5)


def test_tile_choice_does_not_change_result():
    """The blocking is a schedule, not semantics: every legal (c0, k0)
    tile must produce identical numerics."""
    xin = rand(5, (8, 12, 12))
    w = rand(6, (16, 8, 3, 3))
    base = blocked_conv(xin, w, c0=8, k0=16, fh=3, fw=3)
    for c0 in (1, 2, 4, 8):
        for k0 in (1, 4, 16):
            got = blocked_conv(xin, w, c0=c0, k0=k0, fh=3, fw=3)
            # different c0 changes the f32 summation order; allow for it
            np.testing.assert_allclose(got, base, rtol=1e-4, atol=1e-4)


@settings(max_examples=40, deadline=None)
@given(
    c_t=st.integers(0, 2),
    k_t=st.integers(0, 2),
    y=st.integers(1, 10),
    x=st.integers(1, 10),
    fh=st.integers(1, 4),
    fw=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_hypothesis_sweep(c_t, k_t, y, x, fh, fw, seed):
    c, k = 2**c_t * 2, 2**k_t * 2  # smooth channel counts
    c0 = min(2, c)
    k0 = min(4, k)
    xin = rand(seed, (c, y + fh - 1, x + fw - 1))
    w = rand(seed + 1, (k, c, fh, fw))
    got = blocked_conv(xin, w, c0=c0, k0=k0, fh=fh, fw=fw)
    want = conv_ref(xin, w)
    np.testing.assert_allclose(got, want, rtol=3e-5, atol=3e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_kernel_bfloat16(seed):
    xin = rand(seed, (4, 8, 8), dtype=jnp.bfloat16)
    w = rand(seed + 1, (4, 4, 3, 3), dtype=jnp.bfloat16)
    got = blocked_conv(xin, w, c0=2, k0=2, fh=3, fw=3)
    want = conv_ref(xin, w)
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(np.float32), want.astype(np.float32), rtol=5e-2, atol=5e-2
    )


def test_rejects_non_dividing_tiles():
    xin = rand(7, (6, 8, 8))
    w = rand(8, (6, 6, 3, 3))
    with pytest.raises(AssertionError):
        blocked_conv(xin, w, c0=4, k0=6, fh=3, fw=3)


def test_vmem_estimate_positive_and_monotone():
    a = vmem_estimate_bytes(2, 2, 8, 8, 3, 3, 10, 10, 8, 8)
    b = vmem_estimate_bytes(4, 4, 8, 8, 3, 3, 10, 10, 8, 8)
    assert 0 < a < b
