// quick stage-by-stage check
use cnn_blocking::runtime::{Engine, Manifest};
use cnn_blocking::util::json::parse;

fn main() -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from("artifacts");
    let m = Manifest::load(&dir)?;
    let engine = Engine::cpu()?;
    let text = std::fs::read_to_string(dir.join("golden.json"))?;
    let j = parse(&text).unwrap();
    let stages = j.get("stages").unwrap().as_arr().unwrap();
    for st in stages {
        let name = st.get("name").unwrap().as_str().unwrap();
        let input: Vec<f32> = st.get("input").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
        let want: Vec<f32> = st.get("output").unwrap().as_arr().unwrap().iter().map(|v| v.as_f64().unwrap() as f32).collect();
        let module = engine.load(&m.hlo_path(name), m.spec(name)?)?;
        let got = module.run_f32(&[&input])?;
        let err = got.iter().zip(&want).map(|(a,b)| (a-b).abs()).fold(0.0f32, f32::max);
        println!("{}: max err {}", name, err);
    }
    Ok(())
}
