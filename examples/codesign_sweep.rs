//! Fig. 7-style sweep: how energy and area trade off as the SRAM budget
//! grows, for one benchmark layer. Each budget point is planned through
//! the `Planner` facade (via `optimizer::codesign`).
//!
//!     cargo run --release --example codesign_sweep -- [--layer Conv3]

use cnn_blocking::model::benchmarks::by_name;
use cnn_blocking::optimizer::beam::BeamConfig;
use cnn_blocking::optimizer::codesign::{diannao_reference, fig7_budgets, sweep_budgets};
use cnn_blocking::util::cli::Args;
use cnn_blocking::util::table::{energy_pj, Table};

fn main() {
    let args = Args::from_env();
    if let Err(e) = args.reject_unknown(&["layer"]) {
        eprintln!("{}", e);
        std::process::exit(2);
    }
    let name = args.get_or("layer", "Conv3");
    let bench = by_name(&name).expect("unknown layer; see Table 4");
    let cfg = BeamConfig::quick();

    let reference = diannao_reference(&bench.dims, &cfg);
    println!(
        "{}: DianNao baseline {}  /  DianNao + optimal schedule {}",
        bench.name,
        energy_pj(reference.baseline_pj),
        energy_pj(reference.optimized_pj)
    );

    let points = sweep_budgets(&bench.dims, &fig7_budgets(), 3, &cfg);
    let mut t = Table::new(
        &format!("{} energy/area vs SRAM budget", bench.name),
        &["budget", "energy", "vs DianNao-opt", "area mm2", "on-chip", "schedule"],
    );
    for p in &points {
        t.row(vec![
            cnn_blocking::model::hierarchy::human_bytes(p.budget_bytes),
            energy_pj(p.energy_pj),
            format!("{:.1}x", reference.optimized_pj / p.energy_pj),
            format!("{:.2}", p.area_mm2),
            cnn_blocking::model::hierarchy::human_bytes(p.onchip_bytes),
            p.string.clone(),
        ]);
    }
    t.print();
}
