//! Quickstart: model a layer, find its optimal blocking, and inspect the
//! result — the 60-second tour of the public API.
//!
//!     cargo run --release --example quickstart

use cnn_blocking::model::access::analyze;
use cnn_blocking::model::dims::LayerDims;
use cnn_blocking::model::string::BlockingString;
use cnn_blocking::optimizer::beam::{optimize, BeamConfig};
use cnn_blocking::optimizer::targets::{BespokeTarget, Evaluator};
use cnn_blocking::util::table::energy_pj;

fn main() {
    // 1. Describe a convolutional layer (VGG conv4, Table 4 of the paper).
    let layer = LayerDims::conv(56, 56, 128, 256, 3, 3);
    println!("layer: {}   ({} MACs)", layer, layer.macs());

    // 2. Any loop nest is a "blocking string". Algorithm 1, unblocked:
    let naive = BlockingString::unblocked(&layer);
    println!("\nnaive string:   {}", naive);

    // 3. The analytical model turns a string into buffers and accesses.
    let (bufs, _profile) = analyze(&naive, &layer);
    println!("buffers implied by the naive string:");
    for vb in bufs.all() {
        println!(
            "  {}{}  {:>10} elems  refetch-rate {:.1}",
            vb.tensor, vb.ordinal, vb.size_elems, vb.refetch_rate
        );
    }

    // 4. Search for the minimum-energy blocking, co-designing a memory
    //    hierarchy under an 8 MB SRAM budget.
    let target = BespokeTarget::new(8 << 20);
    let naive_pj = target.objective(&naive, &layer);
    let best = optimize(&layer, &target, 3, &BeamConfig::quick())
        .into_iter()
        .next()
        .unwrap();
    println!("\nnaive   energy: {}", energy_pj(naive_pj));
    println!(
        "optimal energy: {}  ({:.1}x better)",
        energy_pj(best.energy_pj),
        naive_pj / best.energy_pj
    );
    println!("optimal string: {}", best.string);

    // 5. The level-0 tile is what parameterizes the Pallas kernel.
    let (x0, y0, c0, k0) = best.string.level0_tile(&layer);
    println!("level-0 tile: x0={} y0={} c0={} k0={}", x0, y0, c0, k0);
}
