//! Quickstart: plan a layer through the `Planner` facade, inspect the
//! resulting `BlockingPlan`, and *execute* it on a real backend — the
//! 60-second tour of the public API. (The `Planner`/plan layer is the
//! front door; the lower-level `optimizer::*` modules are internals.)
//!
//!     cargo run --release --example quickstart

use cnn_blocking::model::dims::LayerDims;
use cnn_blocking::model::string::BlockingString;
use cnn_blocking::optimizer::beam::BeamConfig;
use cnn_blocking::util::table::energy_pj;
use cnn_blocking::{BlockingPlan, ConvInputs, Planner, Target};

fn main() -> anyhow::Result<()> {
    // 1. Describe a convolutional layer (VGG conv4, Table 4 of the paper).
    let layer = LayerDims::conv(56, 56, 128, 256, 3, 3);
    println!("layer: {}   ({} MACs)", layer, layer.macs());

    // 2. The front door: a Planner turns the layer into a BlockingPlan —
    //    searching blockings and co-designing a memory hierarchy under an
    //    8 MB SRAM budget in one call.
    let planner = Planner::for_named("vgg_conv4", layer)
        .target(Target::Bespoke {
            budget_bytes: 8 << 20,
        })
        .levels(3)
        .beam(BeamConfig::quick());
    let plan = planner.plan()?;

    // 3. A plan is the whole story: the chosen blocking string, where
    //    every buffer landed, and the predicted energy/area.
    println!("\nplan:   {}", plan.string);
    println!("energy: {}  ({:.3} pJ/MAC)", energy_pj(plan.outcome.total_pj), plan.pj_per_mac());
    println!(
        "area:   {:.2} mm2  (on-chip {} bytes)",
        plan.outcome.area_mm2, plan.outcome.onchip_bytes
    );
    println!("buffer placement:");
    for b in &plan.buffers {
        println!(
            "  {}{}  {:>10} B  -> {}{}",
            b.tensor,
            b.ordinal,
            b.size_bytes,
            b.level,
            if b.on_chip { "" } else { "  (off-chip)" }
        );
    }

    // 4. How much did planning buy? Evaluate Algorithm 1's unblocked nest
    //    on the same target for comparison.
    let naive = planner.plan_string(&BlockingString::unblocked(&layer))?;
    println!(
        "\nnaive {} vs planned {}  ({:.1}x better)",
        energy_pj(naive.outcome.total_pj),
        energy_pj(plan.outcome.total_pj),
        naive.outcome.total_pj / plan.outcome.total_pj
    );

    // 5. Plans serialize: JSON round-trips exactly, which is what the
    //    PlanCache and the schedules.json export build on.
    let text = plan.to_json().pretty();
    let back = BlockingPlan::from_json(&cnn_blocking::util::json::parse(&text)?)?;
    assert_eq!(back, plan);
    println!("\nJSON round-trip OK ({} bytes)", text.len());

    // 6. The level-0 tile is what parameterizes the Pallas kernel.
    let (x0, y0, c0, k0) = plan.tile;
    println!("level-0 tile: x0={} y0={} c0={} k0={}", x0, y0, c0, k0);

    // 7. Whole networks route through the PlanEngine: repeated layer
    //    shapes are deduped and searched once, unique shapes fan out
    //    across a worker pool, and results flow through the shared plan
    //    cache. The search driver itself is pluggable — try
    //    .strategy_named("random") for the Monte-Carlo baseline.
    let network = Planner::for_network("AlexNet-mini")?
        .levels(2)
        .beam(BeamConfig::quick())
        .strategy_named("beam")?
        .jobs(4)
        .plan_all()?;
    println!("\nAlexNet-mini network plans ({} layers):", network.len());
    for p in &network {
        println!("  {}: {}  ({:.3} pJ/MAC)", p.name, p.string, p.pj_per_mac());
    }

    // 8. Plans are runnable: the backend layer executes the planned loop
    //    nest over real tensors and *measures* per-level access counts
    //    (see `cnnblk run` for the full measured-vs-predicted table).
    //    Execute on dims scaled down for interpretation — full Table 4
    //    layers are ~10^12 MACs.
    let exec_dims = layer.scaled_for_sim(500_000);
    let exec_plan = Planner::for_named("vgg_conv4_mini", exec_dims)
        .levels(2)
        .beam(BeamConfig::quick())
        .plan()?;
    let run = exec_plan.execute(&ConvInputs::synthetic(exec_dims, 42))?;
    println!(
        "\nexecuted {} on the '{}' backend: {} MACs, measured traffic per level:",
        exec_dims,
        run.counters.backend,
        run.counters.macs
    );
    for (level, t) in run.counters.per_level() {
        println!("  {:>10}: {} loads, {} stores", level, t.loads, t.stores);
    }
    Ok(())
}
