//! Optimize every layer of AlexNet and report per-layer energy on a
//! co-designed 1 MB accelerator vs the DianNao fixed hierarchy, plus the
//! multi-layer "flexible memory" shared design (Sec. 3.6).
//!
//!     cargo run --release --example optimize_alexnet

use cnn_blocking::model::networks::{alexnet, LayerKind};
use cnn_blocking::optimizer::beam::{optimize, BeamConfig};
use cnn_blocking::optimizer::multilayer::shared_design;
use cnn_blocking::optimizer::targets::{BespokeTarget, FixedTarget};
use cnn_blocking::util::table::{energy_pj, Table};

fn main() {
    let net = alexnet();
    let cfg = BeamConfig::quick();
    let budget = 1 << 20; // 1 MB on-chip

    let mut t = Table::new(
        "AlexNet per-layer optimal blocking (1 MB co-design vs DianNao-fixed)",
        &["layer", "dims", "DianNao opt", "co-design", "gain", "schedule"],
    );
    let mut conv_dims = Vec::new();
    for l in net.layers.iter().filter(|l| l.kind == LayerKind::Conv) {
        let dn = optimize(&l.dims, &FixedTarget::diannao(), 3, &cfg)
            .into_iter()
            .next()
            .unwrap();
        let cd = optimize(&l.dims, &BespokeTarget::new(budget), 3, &cfg)
            .into_iter()
            .next()
            .unwrap();
        t.row(vec![
            l.name.clone(),
            format!("{}", l.dims),
            energy_pj(dn.energy_pj),
            energy_pj(cd.energy_pj),
            format!("{:.1}x", dn.energy_pj / cd.energy_pj),
            cd.string.notation(),
        ]);
        conv_dims.push(l.dims);
    }
    t.print();

    // Sec. 3.6: one shared memory hierarchy for all five conv layers.
    println!("searching a shared flexible-memory design for all conv layers...");
    let shared = shared_design(&conv_dims, 10.0, 2, &cfg);
    println!(
        "shared design: levels {:?} bytes, area {:.1} mm2, total {}",
        shared.shape.level_bytes,
        shared.area_mm2,
        energy_pj(shared.total_pj)
    );
    for (l, pj) in net
        .layers
        .iter()
        .filter(|l| l.kind == LayerKind::Conv)
        .zip(&shared.per_layer_pj)
    {
        println!("  {}: {}", l.name, energy_pj(*pj));
    }
}
