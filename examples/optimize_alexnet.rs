//! Plan every conv layer of AlexNet through the network facade and report
//! per-layer energy on a co-designed 1 MB accelerator vs the DianNao fixed
//! hierarchy, plus the multi-layer "flexible memory" shared design
//! (Sec. 3.6).
//!
//!     cargo run --release --example optimize_alexnet

use cnn_blocking::optimizer::beam::BeamConfig;
use cnn_blocking::optimizer::multilayer::shared_design;
use cnn_blocking::util::table::{energy_pj, Table};
use cnn_blocking::{Planner, Target};

fn main() -> anyhow::Result<()> {
    let cfg = BeamConfig::quick();
    let budget = 1 << 20; // 1 MB on-chip

    // One facade call plans the whole network on the co-design target.
    // plan_all drives the PlanEngine: unique layer shapes fan out across
    // the worker pool (`.jobs(0)` = all cores) and repeated shapes — of
    // which VGG has many; AlexNet's five convs are all distinct — are
    // searched once.
    let codesigned = Planner::for_network("AlexNet")?
        .target(Target::Bespoke {
            budget_bytes: budget,
        })
        .levels(3)
        .beam(cfg.clone())
        .jobs(0)
        .plan_all()?;
    // ...and a second pass scores the same layers on fixed DianNao.
    let diannao = Planner::for_network("AlexNet")?
        .target(Target::DianNao)
        .levels(3)
        .beam(cfg.clone())
        .plan_all()?;

    let mut t = Table::new(
        "AlexNet per-layer optimal blocking (1 MB co-design vs DianNao-fixed)",
        &["layer", "dims", "DianNao opt", "co-design", "gain", "schedule"],
    );
    let mut conv_dims = Vec::new();
    for (cd, dn) in codesigned.iter().zip(&diannao) {
        t.row(vec![
            cd.name.clone(),
            format!("{}", cd.dims),
            energy_pj(dn.outcome.total_pj),
            energy_pj(cd.outcome.total_pj),
            format!("{:.1}x", dn.outcome.total_pj / cd.outcome.total_pj),
            cd.string.notation(),
        ]);
        conv_dims.push(cd.dims);
    }
    t.print();

    // Sec. 3.6: one shared memory hierarchy for all five conv layers.
    println!("searching a shared flexible-memory design for all conv layers...");
    let shared = shared_design(&conv_dims, 10.0, 2, &cfg);
    println!(
        "shared design: levels {:?} bytes, area {:.1} mm2, total {}",
        shared.shape.level_bytes,
        shared.area_mm2,
        energy_pj(shared.total_pj)
    );
    for (plan, pj) in codesigned.iter().zip(&shared.per_layer_pj) {
        println!("  {}: {}", plan.name, energy_pj(*pj));
    }
    Ok(())
}
