//! Fig. 9-style study: multicore partitioning of a layer under the two
//! schemes of Sec. 3.3, printing the per-component energy breakdown.
//!
//!     cargo run --release --example multicore_scaling -- [--layer Conv1]

use cnn_blocking::figures::fig9;
use cnn_blocking::model::benchmarks::by_name;
use cnn_blocking::optimizer::beam::BeamConfig;
use cnn_blocking::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let name = args.get_or("layer", "Conv1");
    let bench = by_name(&name).expect("unknown layer; see Table 4");
    let cfg = BeamConfig::quick();

    println!("finding top-4 single-core schedules for {}...", bench.name);
    let schedules = fig9::top_schedules(&bench.dims, 4, 8 << 20, &cfg);
    for (i, s) in schedules.iter().enumerate() {
        println!("  sched{}: {}", i + 1, s.notation());
    }

    let cells = fig9::fig9_grid(&bench.dims, &schedules, 8 << 20);
    fig9::render_fig9(&bench.dims, &cells).print();
    println!(
        "paper takeaway (share the dominant buffer -> broadcast is free) holds: {}",
        fig9::takeaway_holds(&bench.dims, &cells)
    );
}
