//! Fig. 9-style study: multicore partitioning of a layer under the two
//! schemes of Sec. 3.3. The single-core `BlockingPlan`s come from the
//! `Planner` facade; `partition_plan` picks the cheaper scheme per plan.
//!
//!     cargo run --release --example multicore_scaling -- [--layer Conv1]

use cnn_blocking::figures::fig9;
use cnn_blocking::model::benchmarks::by_name;
use cnn_blocking::optimizer::beam::BeamConfig;
use cnn_blocking::parallel::partition::partition_plan;
use cnn_blocking::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = args.reject_unknown(&["layer"]) {
        eprintln!("{}", e);
        std::process::exit(2);
    }
    let name = args.get_or("layer", "Conv1");
    let bench = by_name(&name).expect("unknown layer; see Table 4");
    let cfg = BeamConfig::quick();

    println!("finding top-4 single-core plans for {}...", bench.name);
    let plans = fig9::top_plans(&bench.dims, 4, 8 << 20, &cfg);
    for (i, p) in plans.iter().enumerate() {
        println!("  plan{}: {}", i + 1, p.string);
    }

    // the plan-level entry point: best scheme at 8 cores per plan
    println!("\nbest partitioning at 8 cores:");
    for (i, p) in plans.iter().enumerate() {
        let mc = partition_plan(p, 8);
        println!(
            "  plan{}: {}  ({:.2} pJ/MAC)",
            i + 1,
            mc.scheme.name(),
            mc.pj_per_mac()
        );
    }

    let cells = fig9::fig9_grid(&plans);
    fig9::render_fig9(&bench.dims, &cells).print();
    println!(
        "paper takeaway (share the dominant buffer -> broadcast is free) holds: {}",
        fig9::takeaway_holds(&bench.dims, &cells)
    );
}
