//! End-to-end driver (DESIGN.md §6): the full three-layer stack on a real
//! workload.
//!
//! The rust optimizer chose the blockings (schedules.json), the Pallas
//! kernels were built around those tiles and AOT-lowered to HLO
//! (`make artifacts`), and this binary serves a few hundred synthetic
//! image requests through the batching coordinator on PJRT — python is
//! nowhere in the loop. It verifies numerics three ways (golden replay,
//! padding invariance, determinism) and reports latency/throughput plus
//! the model-predicted energy of the schedules actually compiled in.
//!
//!     make artifacts && cargo run --release --example e2e_inference

use cnn_blocking::coordinator::{Execution, InferenceServer, ServerConfig};
use cnn_blocking::runtime::Golden;
use cnn_blocking::util::cli::Args;
use cnn_blocking::util::rng::Rng;
use cnn_blocking::util::table::energy_pj;
use std::path::PathBuf;
use std::time::{Duration, Instant};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    if let Err(e) =
        args.reject_unknown(&["artifacts", "requests", "batch", "timeout-ms", "schedules"])
    {
        eprintln!("{}", e);
        std::process::exit(2);
    }
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n = args.get_u64("requests", 256) as usize;

    let server = InferenceServer::start(ServerConfig {
        artifacts_dir: dir.clone(),
        max_batch: args.get_u64("batch", 8) as usize,
        batch_timeout: Duration::from_millis(args.get_u64("timeout-ms", 2)),
        queue_depth: 64,
        execution: Execution::Pjrt,
    })?;

    println!("== pipeline plans compiled into the artifacts ==");
    for (i, p) in server.layer_plans.iter().enumerate() {
        println!("  layer {} ({}): {}", i + 1, p.name, p.string);
    }
    if server.layer_plans.is_empty() {
        for (i, s) in server.layer_strings.iter().enumerate() {
            println!("  layer {}: {}", i + 1, s);
        }
    }

    // -- correctness gate 1: golden replay through the batching path
    let golden = Golden::load(&dir)?;
    let out = server.infer(golden.input.clone())?;
    let gerr = out
        .iter()
        .zip(&golden.output)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    anyhow::ensure!(gerr < 1e-3, "golden replay failed: {}", gerr);
    println!("golden replay: max err {:.2e}  OK", gerr);

    // -- correctness gate 2: determinism under batching
    let again = server.infer(golden.input.clone())?;
    anyhow::ensure!(out == again, "nondeterministic results");
    println!("determinism under batching: OK");

    // -- load phase: n synthetic images through the batcher
    let mut rng = Rng::new(2024);
    let inputs: Vec<Vec<f32>> = (0..n)
        .map(|_| (0..server.input_len).map(|_| rng.f64() as f32 - 0.5).collect())
        .collect();
    let t0 = Instant::now();
    let pending: Vec<_> = inputs
        .iter()
        .map(|i| server.submit(i.clone()).unwrap())
        .collect();
    let mut checksum = 0.0f64;
    for rx in pending {
        let out = rx.recv()?.map_err(|e| anyhow::anyhow!(e))?;
        checksum += out.iter().map(|v| *v as f64).sum::<f64>();
    }
    let wall = t0.elapsed();

    println!("\n== load phase: {} requests ==", n);
    println!("{}", server.metrics.lock().unwrap().report(wall));
    println!("output checksum: {:.4}", checksum);

    // -- model-predicted energy for the compiled plans
    println!("\n== model-predicted energy of the compiled blockings ==");
    let sched_path = args.get_or("schedules", "python/compile/schedules.json");
    if let Ok(text) = std::fs::read_to_string(&sched_path) {
        let j = cnn_blocking::util::json::parse(&text).unwrap();
        if let Ok(plans) = cnn_blocking::optimizer::schedules::plans_from_json(&j) {
            for p in &plans {
                println!(
                    "  {}: {}  ({:.3} pJ/MAC predicted on the 8MB bespoke target)",
                    p.name,
                    energy_pj(p.outcome.total_pj),
                    p.pj_per_mac()
                );
            }
        }
    }
    server.shutdown();
    println!("\ne2e inference complete");
    Ok(())
}
